#![forbid(unsafe_code)]
//! Builtin decoder-only transformer LM with full manual backprop.
//!
//! This is the native (no-PJRT) gradient engine: it produces *real* Adam
//! moment tensors with the row/column outlier structure the paper
//! analyzes, and powers every convergence experiment that needs to run in
//! milliseconds on CPU. The parameter order matches
//! [`TransformerConfig::param_specs`] exactly.
//!
//! Architecture (GPT-2 style, pre-LN):
//!   x = tok_emb[t] + pos_emb
//!   per layer: x += Wo·Attn(LN1(x));  x += W2·relu(W1·LN2(x) + b1) + b2
//!   logits = LN_f(x) · W_lm

use crate::data::LmBatch;
use crate::model::TransformerConfig;
use crate::optim::Param;
use crate::tensor::Tensor;

use super::mlp::{add_bias, relu_inplace, sum_rows};

const LN_EPS: f32 = 1e-5;

/// Cached LayerNorm statistics for backward.
struct LnCache {
    xhat: Tensor,        // normalized input
    inv_std: Vec<f32>,   // 1/sigma per row
}

/// Per-layer forward cache.
struct LayerCache {
    x_in: Tensor,   // residual stream entering the layer
    ln1: LnCache,
    a1: Tensor,     // LN1 output
    q: Tensor,
    k: Tensor,
    v: Tensor,
    probs: Vec<Tensor>, // attention probs per (batch*head), each [T, T]
    attn_concat: Tensor, // heads concatenated, pre-Wo
    x_mid: Tensor,  // after attention residual
    ln2: LnCache,
    a2: Tensor,     // LN2 output
    h1: Tensor,     // post-ReLU hidden
}

pub struct TransformerEngine {
    pub cfg: TransformerConfig,
}

/// Offsets of each parameter inside the flat parameter vector.
struct Idx;
impl Idx {
    const TOK: usize = 0;
    const POS: usize = 1;
    const PER_LAYER: usize = 12;
    fn layer(l: usize, o: usize) -> usize {
        2 + l * Self::PER_LAYER + o
    }
    // per-layer offsets
    const LN1G: usize = 0;
    const LN1B: usize = 1;
    const WQ: usize = 2;
    const WK: usize = 3;
    const WV: usize = 4;
    const WO: usize = 5;
    const LN2G: usize = 6;
    const LN2B: usize = 7;
    const FC1: usize = 8;
    const B1: usize = 9;
    const FC2: usize = 10;
    const B2: usize = 11;
    fn lnf_g(n_layers: usize) -> usize {
        2 + n_layers * Self::PER_LAYER
    }
    fn lnf_b(n_layers: usize) -> usize {
        Self::lnf_g(n_layers) + 1
    }
    fn lm_head(n_layers: usize) -> usize {
        Self::lnf_g(n_layers) + 2
    }
}

fn layernorm(x: &Tensor, g: &Tensor, b: &Tensor) -> (Tensor, LnCache) {
    let (n, c) = x.dims2();
    let mut out = Tensor::zeros(&[n, c]);
    let mut xhat = Tensor::zeros(&[n, c]);
    let mut inv_std = vec![0.0f32; n];
    for i in 0..n {
        let row = &x.data[i * c..(i + 1) * c];
        let mean: f32 = row.iter().sum::<f32>() / c as f32;
        let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        inv_std[i] = inv;
        for j in 0..c {
            let xh = (row[j] - mean) * inv;
            xhat.data[i * c + j] = xh;
            out.data[i * c + j] = xh * g.data[j] + b.data[j];
        }
    }
    (out, LnCache { xhat, inv_std })
}

/// Backward through LayerNorm. Returns dx; accumulates dg/db.
fn layernorm_backward(
    dy: &Tensor,
    cache: &LnCache,
    g: &Tensor,
    dg: &mut Tensor,
    db: &mut Tensor,
) -> Tensor {
    let (n, c) = dy.dims2();
    let mut dx = Tensor::zeros(&[n, c]);
    for i in 0..n {
        let base = i * c;
        let mut mean_dyg = 0.0f32;
        let mut mean_dyg_xhat = 0.0f32;
        for j in 0..c {
            let dyg = dy.data[base + j] * g.data[j];
            let xh = cache.xhat.data[base + j];
            mean_dyg += dyg;
            mean_dyg_xhat += dyg * xh;
            dg.data[j] += dy.data[base + j] * xh;
            db.data[j] += dy.data[base + j];
        }
        mean_dyg /= c as f32;
        mean_dyg_xhat /= c as f32;
        let inv = cache.inv_std[i];
        for j in 0..c {
            let dyg = dy.data[base + j] * g.data[j];
            let xh = cache.xhat.data[base + j];
            dx.data[base + j] = inv * (dyg - mean_dyg - xh * mean_dyg_xhat);
        }
    }
    dx
}

impl TransformerEngine {
    pub fn new(cfg: TransformerConfig) -> TransformerEngine {
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model % n_heads != 0");
        TransformerEngine { cfg }
    }

    /// Forward + backward over a token batch. Returns (mean next-token CE
    /// loss in nats, grads aligned with `param_specs`).
    pub fn loss_and_grads(&self, params: &[Param], batch: &LmBatch) -> (f32, Vec<Tensor>) {
        let (loss, caches, xf, lnf, logits_probs, flat_targets) =
            self.forward(params, batch, true);
        let grads = self.backward(
            params,
            batch,
            caches.unwrap(),
            xf.unwrap(),
            lnf.unwrap(),
            logits_probs.unwrap(),
            &flat_targets,
        );
        (loss, grads)
    }

    /// Forward only; returns mean loss.
    pub fn loss(&self, params: &[Param], batch: &LmBatch) -> f32 {
        self.forward(params, batch, false).0
    }

    /// Greedy next-token predictions for every position: `[B*T]` argmax of
    /// the output distribution. Used by the evaluation metrics.
    pub fn predictions(&self, params: &[Param], batch: &LmBatch) -> Vec<u32> {
        let (_, _, _, _, probs, _) = self.forward(params, batch, true);
        let probs = probs.expect("forward(keep) returns probs");
        let (n, v) = probs.dims2();
        (0..n)
            .map(|i| {
                let row = &probs.data[i * v..(i + 1) * v];
                let mut best = 0usize;
                for j in 1..v {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect()
    }

    /// Top-1 next-token accuracy over all positions: (correct, total).
    pub fn next_token_accuracy(&self, params: &[Param], batch: &LmBatch) -> (usize, usize) {
        let preds = self.predictions(params, batch);
        let t_len = batch.seq_len();
        let mut correct = 0usize;
        for (b, seq) in batch.tokens.iter().enumerate() {
            for t in 0..t_len {
                if preds[b * t_len + t] == seq[t + 1] {
                    correct += 1;
                }
            }
        }
        (correct, preds.len())
    }

    /// Accuracy restricted to the second half of each sequence (the
    /// "translated" targets of the copy task — the MT surrogate metric).
    pub fn second_half_accuracy(&self, params: &[Param], batch: &LmBatch) -> f64 {
        let preds = self.predictions(params, batch);
        let t_len = batch.seq_len();
        let half = t_len / 2;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (b, seq) in batch.tokens.iter().enumerate() {
            for t in half..t_len {
                total += 1;
                if preds[b * t_len + t] == seq[t + 1] {
                    correct += 1;
                }
            }
        }
        correct as f64 / total.max(1) as f64
    }

    #[allow(clippy::type_complexity)]
    fn forward(
        &self,
        params: &[Param],
        batch: &LmBatch,
        keep: bool,
    ) -> (
        f32,
        Option<Vec<LayerCache>>,
        Option<Tensor>,
        Option<LnCache>,
        Option<Tensor>,
        Vec<u32>,
    ) {
        let cfg = &self.cfg;
        let bsz = batch.batch_size();
        let t_len = batch.seq_len();
        assert!(t_len <= cfg.max_seq, "sequence longer than max_seq");
        let c = cfg.d_model;
        let n = bsz * t_len;
        let heads = cfg.n_heads;
        let hs = c / heads;
        let scale = 1.0 / (hs as f32).sqrt();

        let tok_emb = &params[Idx::TOK].tensor;
        let pos_emb = &params[Idx::POS].tensor;

        // Embedding.
        let mut x = Tensor::zeros(&[n, c]);
        for b in 0..bsz {
            for t in 0..t_len {
                let tok = batch.tokens[b][t] as usize;
                let row = (b * t_len + t) * c;
                for j in 0..c {
                    x.data[row + j] = tok_emb.data[tok * c + j] + pos_emb.data[t * c + j];
                }
            }
        }

        let mut caches: Vec<LayerCache> = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g1 = &params[Idx::layer(l, Idx::LN1G)].tensor;
            let b1 = &params[Idx::layer(l, Idx::LN1B)].tensor;
            let (a1, ln1) = layernorm(&x, g1, b1);
            let q = a1.matmul(&params[Idx::layer(l, Idx::WQ)].tensor);
            let k = a1.matmul(&params[Idx::layer(l, Idx::WK)].tensor);
            let v = a1.matmul(&params[Idx::layer(l, Idx::WV)].tensor);

            // Causal attention per (batch, head).
            let mut probs: Vec<Tensor> = Vec::with_capacity(bsz * heads);
            let mut concat = Tensor::zeros(&[n, c]);
            for b in 0..bsz {
                for h in 0..heads {
                    let mut p = Tensor::zeros(&[t_len, t_len]);
                    for ti in 0..t_len {
                        let qrow = (b * t_len + ti) * c + h * hs;
                        // Scores over u <= ti, in-place softmax.
                        let mut mx = f32::NEG_INFINITY;
                        for u in 0..=ti {
                            let krow = (b * t_len + u) * c + h * hs;
                            let mut s = 0.0f32;
                            for d in 0..hs {
                                s += q.data[qrow + d] * k.data[krow + d];
                            }
                            let s = s * scale;
                            p.data[ti * t_len + u] = s;
                            if s > mx {
                                mx = s;
                            }
                        }
                        let mut z = 0.0f32;
                        for u in 0..=ti {
                            let e = (p.data[ti * t_len + u] - mx).exp();
                            p.data[ti * t_len + u] = e;
                            z += e;
                        }
                        let inv = 1.0 / z;
                        for u in 0..=ti {
                            p.data[ti * t_len + u] *= inv;
                        }
                        // Weighted sum of V.
                        let orow = (b * t_len + ti) * c + h * hs;
                        for u in 0..=ti {
                            let w = p.data[ti * t_len + u];
                            let vrow = (b * t_len + u) * c + h * hs;
                            for d in 0..hs {
                                concat.data[orow + d] += w * v.data[vrow + d];
                            }
                        }
                    }
                    probs.push(p);
                }
            }
            let attn_out = concat.matmul(&params[Idx::layer(l, Idx::WO)].tensor);
            let x_mid = x.add(&attn_out);

            let g2 = &params[Idx::layer(l, Idx::LN2G)].tensor;
            let b2 = &params[Idx::layer(l, Idx::LN2B)].tensor;
            let (a2, ln2) = layernorm(&x_mid, g2, b2);
            let mut h1 = a2.matmul(&params[Idx::layer(l, Idx::FC1)].tensor);
            add_bias(&mut h1, &params[Idx::layer(l, Idx::B1)].tensor);
            relu_inplace(&mut h1);
            let mut h2 = h1.matmul(&params[Idx::layer(l, Idx::FC2)].tensor);
            add_bias(&mut h2, &params[Idx::layer(l, Idx::B2)].tensor);
            let x_out = x_mid.add(&h2);

            if keep {
                caches.push(LayerCache {
                    x_in: x,
                    ln1,
                    a1,
                    q,
                    k,
                    v,
                    probs,
                    attn_concat: concat,
                    x_mid,
                    ln2,
                    a2,
                    h1,
                });
            }
            x = x_out;
        }

        let gf = &params[Idx::lnf_g(cfg.n_layers)].tensor;
        let bf = &params[Idx::lnf_b(cfg.n_layers)].tensor;
        let (xf, lnf) = layernorm(&x, gf, bf);
        let mut logits = xf.matmul(&params[Idx::lm_head(cfg.n_layers)].tensor);

        // Loss + softmax in place (logits become probs).
        let flat_targets: Vec<u32> = (0..bsz)
            .flat_map(|b| (0..t_len).map(move |t| batch.tokens[b][t + 1]))
            .collect();
        logits.softmax_rows();
        let vsz = cfg.vocab;
        let mut loss = 0.0f64;
        for (i, &y) in flat_targets.iter().enumerate() {
            loss -= (logits.data[i * vsz + y as usize].max(1e-12) as f64).ln();
        }
        let loss = (loss / flat_targets.len() as f64) as f32;

        if keep {
            (
                loss,
                Some(caches),
                Some(x),
                Some(lnf),
                Some(logits),
                flat_targets,
            )
        } else {
            (loss, None, None, None, None, flat_targets)
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn backward(
        &self,
        params: &[Param],
        batch: &LmBatch,
        caches: Vec<LayerCache>,
        x_final: Tensor,
        lnf: LnCache,
        mut probs_logits: Tensor,
        flat_targets: &[u32],
    ) -> Vec<Tensor> {
        let cfg = &self.cfg;
        let bsz = batch.batch_size();
        let t_len = batch.seq_len();
        let c = cfg.d_model;
        let heads = cfg.n_heads;
        let hs = c / heads;
        let scale = 1.0 / (hs as f32).sqrt();
        let vsz = cfg.vocab;
        let ntok = flat_targets.len();

        let mut grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros(&p.tensor.shape))
            .collect();

        // dlogits = (probs - onehot) / ntok
        let inv_n = 1.0 / ntok as f32;
        for (i, &y) in flat_targets.iter().enumerate() {
            probs_logits.data[i * vsz + y as usize] -= 1.0;
        }
        for v in probs_logits.data.iter_mut() {
            *v *= inv_n;
        }
        let dlogits = probs_logits;

        // lm head: logits = xf @ W
        let xf = {
            // recompute xf from cache: xhat * g + b
            let gf = &params[Idx::lnf_g(cfg.n_layers)].tensor;
            let bf = &params[Idx::lnf_b(cfg.n_layers)].tensor;
            let (n, _) = lnf.xhat.dims2();
            let mut out = Tensor::zeros(&[n, c]);
            for i in 0..n {
                for j in 0..c {
                    out.data[i * c + j] =
                        lnf.xhat.data[i * c + j] * gf.data[j] + bf.data[j];
                }
            }
            out
        };
        grads[Idx::lm_head(cfg.n_layers)] = xf.matmul_tn(&dlogits);
        let dxf = dlogits.matmul_nt(&params[Idx::lm_head(cfg.n_layers)].tensor);

        // Final LN backward.
        let _ = x_final;
        let mut dgf = Tensor::zeros(&[c]);
        let mut dbf = Tensor::zeros(&[c]);
        let mut dx = layernorm_backward(
            &dxf,
            &lnf,
            &params[Idx::lnf_g(cfg.n_layers)].tensor,
            &mut dgf,
            &mut dbf,
        );
        grads[Idx::lnf_g(cfg.n_layers)] = dgf;
        grads[Idx::lnf_b(cfg.n_layers)] = dbf;

        for l in (0..cfg.n_layers).rev() {
            let cache = &caches[l];
            // ---- MLP block ----
            // x_out = x_mid + h2; dh2 = dx (residual passes dx through).
            let dh2 = dx.clone();
            grads[Idx::layer(l, Idx::B2)] = sum_rows(&dh2);
            grads[Idx::layer(l, Idx::FC2)] = cache.h1.matmul_tn(&dh2);
            let mut dh1 = dh2.matmul_nt(&params[Idx::layer(l, Idx::FC2)].tensor);
            for (dv, hv) in dh1.data.iter_mut().zip(cache.h1.data.iter()) {
                if *hv <= 0.0 {
                    *dv = 0.0;
                }
            }
            grads[Idx::layer(l, Idx::B1)] = sum_rows(&dh1);
            grads[Idx::layer(l, Idx::FC1)] = cache.a2.matmul_tn(&dh1);
            let da2 = dh1.matmul_nt(&params[Idx::layer(l, Idx::FC1)].tensor);
            let mut dg2 = Tensor::zeros(&[c]);
            let mut db2 = Tensor::zeros(&[c]);
            let dx_mid_from_ln = layernorm_backward(
                &da2,
                &cache.ln2,
                &params[Idx::layer(l, Idx::LN2G)].tensor,
                &mut dg2,
                &mut db2,
            );
            grads[Idx::layer(l, Idx::LN2G)] = dg2;
            grads[Idx::layer(l, Idx::LN2B)] = db2;
            // dx_mid = residual + LN path.
            let dx_mid = dx.add(&dx_mid_from_ln);

            // ---- Attention block ----
            // x_mid = x_in + concat @ Wo
            let dattn_out = dx_mid.clone();
            grads[Idx::layer(l, Idx::WO)] = cache.attn_concat.matmul_tn(&dattn_out);
            let dconcat = dattn_out.matmul_nt(&params[Idx::layer(l, Idx::WO)].tensor);

            let n = bsz * t_len;
            let mut dq = Tensor::zeros(&[n, c]);
            let mut dk = Tensor::zeros(&[n, c]);
            let mut dv = Tensor::zeros(&[n, c]);
            for b in 0..bsz {
                for h in 0..heads {
                    let p = &cache.probs[b * heads + h];
                    for ti in 0..t_len {
                        let orow = (b * t_len + ti) * c + h * hs;
                        // dP[ti,u] = dO . V[u]; dV[u] += P * dO
                        let mut dp = vec![0.0f32; ti + 1];
                        for u in 0..=ti {
                            let vrow = (b * t_len + u) * c + h * hs;
                            let w = p.data[ti * t_len + u];
                            let mut acc = 0.0f32;
                            for d in 0..hs {
                                let dov = dconcat.data[orow + d];
                                acc += dov * cache.v.data[vrow + d];
                                dv.data[vrow + d] += w * dov;
                            }
                            dp[u] = acc;
                        }
                        // Softmax backward: dS = P * (dP - sum(P*dP)).
                        let mut dot = 0.0f32;
                        for u in 0..=ti {
                            dot += p.data[ti * t_len + u] * dp[u];
                        }
                        let qrow = (b * t_len + ti) * c + h * hs;
                        for u in 0..=ti {
                            let ds = p.data[ti * t_len + u] * (dp[u] - dot) * scale;
                            let krow = (b * t_len + u) * c + h * hs;
                            for d in 0..hs {
                                dq.data[qrow + d] += ds * cache.k.data[krow + d];
                                dk.data[krow + d] += ds * cache.q.data[qrow + d];
                            }
                        }
                    }
                }
            }

            grads[Idx::layer(l, Idx::WQ)] = cache.a1.matmul_tn(&dq);
            grads[Idx::layer(l, Idx::WK)] = cache.a1.matmul_tn(&dk);
            grads[Idx::layer(l, Idx::WV)] = cache.a1.matmul_tn(&dv);
            let mut da1 = dq.matmul_nt(&params[Idx::layer(l, Idx::WQ)].tensor);
            da1 = da1.add(&dk.matmul_nt(&params[Idx::layer(l, Idx::WK)].tensor));
            da1 = da1.add(&dv.matmul_nt(&params[Idx::layer(l, Idx::WV)].tensor));

            let mut dg1 = Tensor::zeros(&[c]);
            let mut db1 = Tensor::zeros(&[c]);
            let dx_in_from_ln = layernorm_backward(
                &da1,
                &cache.ln1,
                &params[Idx::layer(l, Idx::LN1G)].tensor,
                &mut dg1,
                &mut db1,
            );
            grads[Idx::layer(l, Idx::LN1G)] = dg1;
            grads[Idx::layer(l, Idx::LN1B)] = db1;
            dx = dx_mid.add(&dx_in_from_ln);
            let _ = &cache.x_in;
        }

        // Embedding scatter.
        for b in 0..bsz {
            for t in 0..t_len {
                let tok = batch.tokens[b][t] as usize;
                let row = (b * t_len + t) * c;
                for j in 0..c {
                    grads[Idx::TOK].data[tok * c + j] += dx.data[row + j];
                    grads[Idx::POS].data[t * c + j] += dx.data[row + j];
                }
            }
        }

        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MarkovCorpus;
    use crate::optim::{build, Hyper};
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> TransformerConfig {
        TransformerConfig {
            vocab: 11,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_layers: 2,
            max_seq: 6,
        }
    }

    #[test]
    fn gradient_check_finite_differences() {
        let cfg = tiny_cfg();
        let engine = TransformerEngine::new(cfg);
        let mut rng = Pcg64::seeded(99);
        let mut params = cfg.init_params(&mut rng);
        // Perturb params away from init symmetry.
        for p in params.iter_mut() {
            for v in p.tensor.data.iter_mut() {
                *v += rng.normal() * 0.05;
            }
        }
        let batch = LmBatch {
            tokens: vec![vec![1, 5, 3, 9, 2], vec![4, 4, 0, 10, 7]],
        };
        let (_, grads) = engine.loss_and_grads(&params, &batch);
        let eps = 1e-2f32;
        for pi in 0..params.len() {
            let n = params[pi].tensor.numel();
            for k in [0usize, n / 3, n - 1] {
                let orig = params[pi].tensor.data[k];
                params[pi].tensor.data[k] = orig + eps;
                let lp = engine.loss(&params, &batch);
                params[pi].tensor.data[k] = orig - eps;
                let lm = engine.loss(&params, &batch);
                params[pi].tensor.data[k] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[pi].data[k];
                let tol = 3e-2 * (1.0 + fd.abs().max(an.abs()));
                assert!(
                    (fd - an).abs() < tol,
                    "param {pi} ({}) coord {k}: fd={fd} analytic={an}",
                    params[pi].name
                );
            }
        }
    }

    #[test]
    fn loss_starts_near_uniform_entropy() {
        let cfg = tiny_cfg();
        let engine = TransformerEngine::new(cfg);
        let mut rng = Pcg64::seeded(1);
        let params = cfg.init_params(&mut rng);
        let batch = LmBatch {
            tokens: vec![vec![0, 1, 2, 3, 4], vec![5, 6, 7, 8, 9]],
        };
        let loss = engine.loss(&params, &batch);
        let uniform = (cfg.vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 0.5,
            "initial loss {loss} vs ln(V) {uniform}"
        );
    }

    #[test]
    fn trains_below_entropy_gap_on_markov_corpus() {
        let cfg = TransformerConfig {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            d_ff: 64,
            n_layers: 1,
            max_seq: 16,
        };
        let engine = TransformerEngine::new(cfg);
        let corpus = MarkovCorpus::new(cfg.vocab, 7);
        let mut rng = Pcg64::seeded(3);
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build("adamw32", Hyper::default()).unwrap();
        let mut first = None;
        let mut last = 0.0f32;
        for step in 0..120 {
            let batch = corpus.sample(8, 16, &mut rng);
            let (loss, grads) = engine.loss_and_grads(&params, &batch);
            if step == 0 {
                first = Some(loss);
            }
            last = loss;
            opt.step(&mut params, &grads, 2e-3);
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.8,
            "loss should drop: first {first} last {last}"
        );
        // Should approach (not necessarily reach) the corpus entropy floor.
        let floor = corpus.entropy_floor(100, &mut rng) as f32;
        assert!(last > floor * 0.5, "loss {last} below plausible floor {floor}");
    }
}
