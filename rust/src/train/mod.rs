//! Builtin (native, no-PJRT) training: gradient engines with manual
//! backprop, the generic trainer loop, and checkpointing.

pub mod checkpoint;
pub mod mlp;
pub mod trainer;
pub mod transformer;

pub use mlp::MlpEngine;
pub use trainer::{GradEngine, LrSchedule, Trainer, TrainReport};
pub use transformer::TransformerEngine;
