#![forbid(unsafe_code)]
//! Tab. 6 reproduction: which-moment ablation (paper: Swin-T pretraining
//! on ImageNet; ours: the MLP classification surrogate, accuracy %).
//! Rows: no quantization → first moment only (B2048 vs B128) → both
//! moments → both + factored v. Expected shape: small monotone-ish drops,
//! B128 better than B2048 on the first moment, everything within ~1 point
//! of fp32.

use super::common::{compressed, exp_seed, metric_cell, run_cls_spread, ExpContext};
use crate::model::MlpConfig;
use crate::optim::lowbit::QuantPolicy;
use crate::optim::{build, Hyper, Optimizer};
use crate::quant::{MapKind, NormKind, Quantizer};
use crate::util::table::Table;

struct Row {
    label: [&'static str; 3],
    build: fn(Hyper) -> Box<dyn Optimizer>,
}

fn rows() -> Vec<Row> {
    vec![
        Row {
            label: ["-", "-", "No"],
            build: |hp| build("adamw32", hp).unwrap(),
        },
        Row {
            label: ["B2048/DE", "-", "No"],
            build: |hp| {
                let m = Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true);
                Box::new(compressed(
                    hp,
                    QuantPolicy::bit4().with_m(Some(m)).with_v(None),
                ))
            },
        },
        Row {
            label: ["B128/DE", "-", "No"],
            build: |hp| Box::new(compressed(hp, QuantPolicy::bit4().with_v(None))),
        },
        Row {
            label: ["B128/DE", "Rank-1/Linear", "No"],
            build: |hp| Box::new(compressed(hp, QuantPolicy::bit4())),
        },
        Row {
            label: ["B128/DE", "Rank-1/Linear", "Yes"],
            build: |hp| Box::new(compressed(hp, QuantPolicy::bit4().factored())),
        },
    ]
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let hp = Hyper::default();
    // Harder surrogate (16 overlapping classes) so moment-compression
    // effects are visible above the task's accuracy ceiling.
    let cfg = MlpConfig {
        d_in: 24,
        d_hidden: 96,
        n_layers: 3,
        n_classes: 16,
    };
    let mut table = Table::new(
        "Table 6 — impact of compressing each moment (classification \
         surrogate for Swin-T/ImageNet; accuracy %)",
        &["Quant. 1st", "Quant. 2nd", "Factor. 2nd", "Acc."],
    );
    for row in rows() {
        let mut accs = Vec::new();
        for s in 0..ctx.seeds() {
            let mut opt = (row.build)(hp);
            let out = run_cls_spread(
                cfg,
                29,
                opt.as_mut(),
                ctx.cls_steps(),
                exp_seed(&format!("table6/{:?}", row.label), s),
                0.8,
            );
            accs.push(out.accuracy * 100.0);
        }
        table.row(&[
            row.label[0].to_string(),
            row.label[1].to_string(),
            row.label[2].to_string(),
            metric_cell(&accs, 1),
        ]);
    }
    vec![table]
}
