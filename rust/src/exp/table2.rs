#![forbid(unsafe_code)]
//! Tab. 2 reproduction: seven optimizers × five tasks.
//!
//! Task surrogates (DESIGN.md §3): NLU/CLS → two classification datasets
//! (accuracy), NLG → LM score, QA → held-out next-token accuracy on a
//! second corpus, MT → copy-translation second-half accuracy. Expected
//! shape: 4-bit AdamW / 4-bit Factor within noise of 32-bit AdamW;
//! SM3 and Adafactor(β1=0) degrade, most visibly on the CLS surrogate.

use super::common::{
    exp_seed, metric_cell, preset_optimizer, run_cls, run_cls_spread, run_copy_task, run_lm,
    ExpContext, LmWorkload,
};
use crate::model::MlpConfig;
use crate::optim::{table2_presets, Hyper};
use crate::util::table::Table;

fn display(preset: &str) -> &'static str {
    match preset {
        "adamw32" => "32-bit AdamW",
        "adafactor" => "32-bit Adafactor",
        "adafactor-b0" => "32-bit Adafactor (b1=0)",
        "sm3" => "32-bit SM3",
        "adamw8" => "8-bit AdamW",
        "adamw4" => "4-bit AdamW (ours)",
        "factor4" => "4-bit Factor (ours)",
        _ => "?",
    }
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let hp = Hyper::default();
    let mut table = Table::new(
        "Table 2 — optimizers across tasks (all metrics: %, higher better; \
         paper tasks: NLU/CLS/NLG/QA/MT)",
        &["Optimizer", "NLU", "CLS", "NLG", "QA", "MT"],
    );
    let nlu_cfg = MlpConfig {
        d_in: 24,
        d_hidden: 64,
        n_layers: 2,
        n_classes: 6,
    };
    let cls_cfg = MlpConfig {
        d_in: 32,
        d_hidden: 96,
        n_layers: 3,
        n_classes: 10,
    };
    let w_nlg = LmWorkload::standard();
    let mut w_qa = LmWorkload::standard();
    w_qa.corpus_seed = 4321;

    for preset in table2_presets() {
        let mut nlu = Vec::new();
        let mut cls = Vec::new();
        let mut nlg = Vec::new();
        let mut qa = Vec::new();
        let mut mt = Vec::new();
        for s in 0..ctx.seeds() {
            let seed = exp_seed(&format!("table2/{preset}"), s);
            let mut o = preset_optimizer(preset, hp);
            nlu.push(run_cls(nlu_cfg, 17, o.as_mut(), ctx.cls_steps(), seed).accuracy * 100.0);
            let mut o = preset_optimizer(preset, hp);
            cls.push(
                run_cls_spread(cls_cfg, 29, o.as_mut(), ctx.cls_steps(), seed ^ 1, 0.9)
                    .accuracy
                    * 100.0,
            );
            let mut o = preset_optimizer(preset, hp);
            nlg.push(run_lm(&w_nlg, o.as_mut(), ctx.lm_steps(), seed ^ 2).eval_acc * 100.0);
            let mut o = preset_optimizer(preset, hp);
            qa.push(run_lm(&w_qa, o.as_mut(), ctx.lm_steps(), seed ^ 3).eval_acc * 100.0);
            let mut o = preset_optimizer(preset, hp);
            mt.push(run_copy_task(o.as_mut(), ctx.lm_steps(), seed ^ 4).1 * 100.0);
        }
        table.row(&[
            display(preset).to_string(),
            metric_cell(&nlu, 1),
            metric_cell(&cls, 1),
            metric_cell(&nlg, 1),
            metric_cell(&qa, 1),
            metric_cell(&mt, 1),
        ]);
    }
    vec![table]
}
