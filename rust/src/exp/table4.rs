#![forbid(unsafe_code)]
//! Tab. 4 reproduction: memory and time per optimizer.
//!
//! Two sub-tables:
//! 1. **Measured** on this testbed — the small builtin transformer: wall
//!    time per optimizer step, exact persistent state bytes, and savings
//!    vs 32-bit. Includes the AOT fused path when artifacts are present.
//! 2. **Modeled** for the paper's models (LLaMA-7B / RoBERTa-L /
//!    GPT-2-M): total training memory from the exact state accounting +
//!    activation model, plus the offload-communication speedup from
//!    `offload::simulate_step` (the paper's reduced-communication claim).

use super::common::{preset_optimizer, ExpContext};
use crate::memory::{training_bytes, StatePreset, TrainSetup, GB};
use crate::model::TransformerConfig;
use crate::offload::{simulate_step, LinkModel, OffloadConfig, OffloadReport};
use crate::optim::adamw::AdamW;
use crate::optim::lowbit::{CompressedAdamW, QuantPolicy};
use crate::optim::{Hyper, Optimizer, Param};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::util::stats::{fmt_bytes, Timer};
use crate::util::table::Table;

/// Paper-model configs for the modeled sub-table.
fn paper_models() -> Vec<(&'static str, TransformerConfig)> {
    vec![
        ("LLaMA-7B", crate::model::llama_family()[0].cfg),
        (
            "RoBERTa-L",
            TransformerConfig {
                vocab: 50265,
                d_model: 1024,
                n_heads: 16,
                d_ff: 4096,
                n_layers: 24,
                max_seq: 512,
            },
        ),
        (
            "GPT-2 Medium",
            TransformerConfig {
                vocab: 50257,
                d_model: 1024,
                n_heads: 16,
                d_ff: 4096,
                n_layers: 24,
                max_seq: 1024,
            },
        ),
    ]
}

fn measured_table(ctx: &ExpContext) -> Table {
    let mut table = Table::new(
        "Table 4a — measured optimizer step time & state memory \
         (builtin small transformer, this CPU)",
        &["Optimizer", "Step time (ms)", "State mem", "Saved vs 32-bit"],
    );
    let cfg = TransformerConfig::small();
    let mut rng = Pcg64::seeded(123);
    let reps = if ctx.quick { 3 } else { 10 };
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let hp = Hyper::default();
    let mut baseline_bytes = 0usize;
    for preset in ["adamw32", "adamw8", "adamw4", "factor4"] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let mut opt = preset_optimizer(preset, hp);
        // Warm-up step (lazy init + map build).
        opt.step(&mut params, &grads, 1e-3);
        let timer = Timer::start();
        for _ in 0..reps {
            opt.step(&mut params, &grads, 1e-3);
        }
        let ms = timer.millis() / reps as f64;
        let bytes = opt.state_bytes();
        if preset == "adamw32" {
            baseline_bytes = bytes;
        }
        let saved = if baseline_bytes > 0 {
            format!(
                "{} ({:.1}%)",
                fmt_bytes((baseline_bytes - bytes) as u64),
                100.0 * (baseline_bytes - bytes) as f64 / baseline_bytes as f64
            )
        } else {
            "-".into()
        };
        table.row(&[
            opt.name(),
            format!("{ms:.1}"),
            fmt_bytes(bytes as u64),
            saved,
        ]);
    }
    // Fused AOT path if artifacts are available.
    let dir = crate::util::artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        if let Ok(rt) = crate::runtime::Runtime::cpu() {
            if let Ok(mut fused) = crate::runtime::fused::FusedAdamW4::load(&rt, &dir, hp) {
                let mut params: Vec<Param> = cfg.init_params(&mut rng);
                fused.step(&mut params, &grads, 1e-3);
                let timer = Timer::start();
                for _ in 0..reps {
                    fused.step(&mut params, &grads, 1e-3);
                }
                let ms = timer.millis() / reps as f64;
                let bytes = fused.state_bytes();
                table.row(&[
                    fused.name(),
                    format!("{ms:.1}"),
                    fmt_bytes(bytes as u64),
                    format!(
                        "{} ({:.1}%)",
                        fmt_bytes((baseline_bytes.saturating_sub(bytes)) as u64),
                        100.0 * (baseline_bytes.saturating_sub(bytes)) as f64
                            / baseline_bytes.max(1) as f64
                    ),
                ]);
            }
        }
    }
    table
}

fn modeled_table() -> Table {
    let mut table = Table::new(
        "Table 4b — modeled training memory & offload step time \
         (paper models; exact state accounting + activation/link model)",
        &["Model", "Optimizer", "Total mem", "Saved", "Offload step (rel.)"],
    );
    for (name, cfg) in paper_models() {
        let setup = TrainSetup {
            batch: 1,
            seq: 512.min(cfg.max_seq),
        };
        // Compute time per step scales with parameter count; calibrated so
        // LLaMA-7B lands near the paper's measured ~4 s/step on 2xA100.
        let compute = 4.0 * cfg.n_params() as f64 / 6.9e9;
        let link = LinkModel::pcie_offload(compute);
        let base = training_bytes(&cfg, StatePreset::AdamW32, setup);
        let base_step = simulate_step(&cfg, StatePreset::AdamW32, &link).step_seconds;
        for preset in [
            StatePreset::AdamW32,
            StatePreset::AdamW8,
            StatePreset::AdamW4,
            StatePreset::Factor4,
        ] {
            let total = training_bytes(&cfg, preset, setup);
            let step = simulate_step(&cfg, preset, &link).step_seconds;
            table.row(&[
                name.to_string(),
                preset.label().to_string(),
                format!("{:.2} GB", total as f64 / GB as f64),
                format!("{:.1}%", 100.0 * (base - total) as f64 / base as f64),
                format!("{:.2}x", base_step / step),
            ]);
        }
    }
    table
}

/// Table 4c (`--measured`): run *real* offloaded optimizer steps on the
/// builtin transformer through the executable pipeline
/// ([`crate::offload::pipeline`]) and put the measured virtual-time
/// speedups next to the analytic model's. The two agree up to the
/// pipeline's documented divergences (per-transfer latency, the phase-C
/// re-download of globally-normalized codes, edge effects) — the
/// convergence itself is pinned by `rust/tests/offload_pipeline.rs`.
fn measured_offload_table(ctx: &ExpContext) -> Table {
    let mut table = Table::new(
        "Table 4c — executable offload pipeline (PCIe profile, builtin \
         transformer): measured virtual step time vs the analytic model",
        &[
            "Optimizer",
            "Analytic step",
            "Pipeline step",
            "Analytic speedup",
            "Measured speedup",
            "Overlap",
        ],
    );
    let cfg = if ctx.quick {
        TransformerConfig::tiny()
    } else {
        TransformerConfig::small()
    };
    let mut rng = Pcg64::seeded(321);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let hp = Hyper::default();
    // Same compute calibration as the modeled sub-table.
    let compute = 4.0 * cfg.n_params() as f64 / 6.9e9;
    let link = LinkModel::pcie_offload(compute);
    let steps = if ctx.quick { 2 } else { 4 };
    let analytic32 = simulate_step(&cfg, StatePreset::AdamW32, &link).step_seconds;
    let mut measured32 = 0.0f64;
    for (name, preset) in [("adamw32", StatePreset::AdamW32), ("adamw4", StatePreset::AdamW4)] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let ocfg = OffloadConfig::new(link, 2);
        let report: OffloadReport = if name == "adamw32" {
            let mut opt = AdamW::new(hp).offloaded(ocfg);
            for _ in 0..steps {
                opt.step(&mut params, &grads, 1e-3);
            }
            *opt.offload_report().expect("offload configured")
        } else {
            let mut opt = CompressedAdamW::new(hp, QuantPolicy::bit4()).offloaded(ocfg);
            for _ in 0..steps {
                opt.step(&mut params, &grads, 1e-3);
            }
            *opt.offload_report().expect("offload configured")
        };
        let measured = report.step_seconds();
        if name == "adamw32" {
            measured32 = measured;
        }
        let analytic = simulate_step(&cfg, preset, &link).step_seconds;
        table.row(&[
            name.to_string(),
            format!("{:.2} ms", analytic * 1e3),
            format!("{:.2} ms", measured * 1e3),
            format!("{:.2}x", analytic32 / analytic),
            format!("{:.2}x", measured32 / measured),
            format!("{:.0}%", 100.0 * report.overlap_fraction()),
        ]);
    }
    table
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let mut tables = vec![measured_table(ctx), modeled_table()];
    if ctx.measured {
        tables.push(measured_offload_table(ctx));
    }
    tables
}
