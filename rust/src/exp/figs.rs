#![forbid(unsafe_code)]
//! Figure reproductions (1, 2, 3, 4). Figures are emitted as tables of
//! the underlying series plus ASCII histograms; full series go to
//! `results/*.json` for plotting.

use super::common::{compressed, exp_seed, ExpContext, LmWorkload};
use crate::data::{LmBatch, MarkovCorpus};
use crate::optim::adamw::AdamW;
use crate::optim::lowbit::QuantPolicy;
use crate::optim::{build, Hyper, Optimizer, Param};
use crate::quant::error::{inv_sqrt_overshoot, inv_sqrt_transform, reconstruction_error, zero_fraction};
use crate::quant::{MapKind, NormKind, Quantizer};
use crate::tensor::Tensor;
use crate::train::{LrSchedule, Trainer, TransformerEngine};
use crate::util::rng::Pcg64;
use crate::util::stats::Histogram;
use crate::util::table::Table;

/// Train the standard workload with fp32 AdamW and return (params,
/// optimizer) so the captured moment tensors can be analyzed.
fn capture_moments(ctx: &ExpContext, seed: u64) -> (Vec<Param>, AdamW) {
    let w = LmWorkload::standard();
    let engine = TransformerEngine::new(w.cfg);
    let corpus = MarkovCorpus::new(w.cfg.vocab, w.corpus_seed);
    let mut rng = Pcg64::new(seed, 51);
    let mut params = w.cfg.init_params(&mut rng);
    let mut opt = AdamW::new(Hyper::default());
    let steps = ctx.lm_steps();
    let trainer = Trainer::new(steps, LrSchedule::Constant(w.lr));
    let mut data_rng = Pcg64::new(seed, 52);
    let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    trainer.run(&mut params, &mut opt, &mut engine_fn, |_| {
        corpus.sample(w.batch, w.cfg.max_seq, &mut data_rng)
    });
    (params, opt)
}

fn find_param(params: &[Param], fragment: &str) -> usize {
    params
        .iter()
        .position(|p| p.name.contains(fragment))
        .unwrap_or_else(|| panic!("no param containing '{fragment}'"))
}

// ---------------------------------------------------------------------
// Fig. 1: first-moment approximation, B128/DE vs B2048/DE.
// ---------------------------------------------------------------------

pub fn fig1(ctx: &ExpContext) -> Vec<Table> {
    let (params, opt) = capture_moments(ctx, exp_seed("fig1", 0));
    let mut table = Table::new(
        "Figure 1 — first-moment approximation error by block size \
         (captured Adam moments; paper: layers.3.blocks.1.mlp.fc1 of Swin-T)",
        &["Tensor", "Quantizer", "MSE", "MeanAbsErr", "Hist (dequant, log10|m|)"],
    );
    for frag in ["mlp.fc1", "attn.wo", "tok_emb"] {
        let idx = find_param(&params, frag);
        let (m, _) = opt.moments(idx).expect("moments");
        for (name, q) in [
            (
                "B128/DE",
                Quantizer::new(NormKind::Block(128), MapKind::DynExp, 4, true),
            ),
            (
                "B2048/DE",
                Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true),
            ),
        ] {
            let mut rng = Pcg64::seeded(0);
            let deq = q.quantize(m, &mut rng).dequantize();
            let err = reconstruction_error(m, &deq);
            let mut h = Histogram::new(-8.0, 0.0, 24);
            h.extend(deq.data.iter().map(|&x| (x.abs().max(1e-12) as f64).log10()));
            table.row(&[
                params[idx].name.clone(),
                name.to_string(),
                format!("{:.3e}", err.mse),
                format!("{:.3e}", err.mean_abs),
                h.sparkline(),
            ]);
        }
    }
    vec![table]
}

// ---------------------------------------------------------------------
// Fig. 2: outlier patterns vary across tensors (rows vs columns).
// ---------------------------------------------------------------------

/// Outlier concentration of a 2-D tensor along an axis: max slice
/// max-magnitude over median slice max-magnitude. ≫1 means outliers
/// concentrate in a few slices of that axis.
fn concentration(m: &Tensor, axis: usize) -> f64 {
    let (r, c) = m.dims2();
    let n_slices = if axis == 0 { r } else { c };
    let mut maxes = vec![0.0f64; n_slices];
    for i in 0..r {
        for j in 0..c {
            let a = m.at2(i, j).abs() as f64;
            let s = if axis == 0 { i } else { j };
            if a > maxes[s] {
                maxes[s] = a;
            }
        }
    }
    let med = crate::util::stats::median(&maxes).max(1e-20);
    maxes.iter().cloned().fold(0.0, f64::max) / med
}

pub fn fig2(ctx: &ExpContext) -> Vec<Table> {
    let (params, opt) = capture_moments(ctx, exp_seed("fig2", 0));
    let mut table = Table::new(
        "Figure 2 — outlier patterns vary across first-moment tensors \
         (concentration = max/median of per-slice max |m|)",
        &["Tensor", "Row conc.", "Col conc.", "Dominant axis"],
    );
    for p in &params {
        if p.tensor.ndim() != 2 || p.tensor.numel() < 1024 {
            continue;
        }
        let idx = find_param(&params, &p.name);
        let (m, _) = opt.moments(idx).unwrap();
        let rc = concentration(m, 0);
        let cc = concentration(m, 1);
        let dom = if rc > cc * 1.3 {
            "rows"
        } else if cc > rc * 1.3 {
            "columns"
        } else {
            "mixed"
        };
        table.row(&[
            p.name.clone(),
            format!("{rc:.1}"),
            format!("{cc:.1}"),
            dom.to_string(),
        ]);
    }
    vec![table]
}

// ---------------------------------------------------------------------
// Fig. 3: the zero-point problem on the second moment.
// ---------------------------------------------------------------------

pub fn fig3(ctx: &ExpContext) -> Vec<Table> {
    let (params, opt) = capture_moments(ctx, exp_seed("fig3", 0));
    let idx = find_param(&params, "tok_emb");
    let (_, v) = opt.moments(idx).expect("moments");
    let eps = 1e-6f32;
    let mut table = Table::new(
        "Figure 3 — histogram of 1/(sqrt(v)+eps) (log10 scale): the \
         zero-point problem. DE collapses mass to 1/eps = 1e6; DE-0 and \
         Linear do not.",
        &["Variant", "zero frac", "inv-sqrt overshoot", "Hist log10 h(v)"],
    );
    let mut variants: Vec<(String, Tensor)> = vec![("fp32".into(), v.clone())];
    for (name, block, map) in [
        ("B2048/DE", 2048usize, MapKind::DynExp),
        ("B2048/DE-0", 2048, MapKind::DynExpNoZero),
        ("B128/DE", 128, MapKind::DynExp),
        ("B128/DE-0", 128, MapKind::DynExpNoZero),
        ("B128/Linear", 128, MapKind::Linear),
    ] {
        let q = Quantizer::new(NormKind::Block(block), map, 4, false);
        let mut rng = Pcg64::seeded(0);
        variants.push((name.into(), q.quantize(v, &mut rng).dequantize()));
    }
    for (name, vv) in &variants {
        let h_t = inv_sqrt_transform(vv, eps);
        let mut h = Histogram::new(0.0, 6.5, 26);
        h.extend(h_t.data.iter().map(|&x| (x.max(1e-12) as f64).log10()));
        table.row(&[
            name.clone(),
            format!("{:.3}", zero_fraction(vv)),
            format!("{:.3}", inv_sqrt_overshoot(v, vv, eps)),
            h.sparkline(),
        ]);
    }
    vec![table]
}

// ---------------------------------------------------------------------
// Fig. 4: training loss curves, 4-bit vs 32-bit AdamW.
// ---------------------------------------------------------------------

pub fn fig4(ctx: &ExpContext) -> Vec<Table> {
    let w = LmWorkload::standard();
    let hp = Hyper::default();
    let steps = ctx.lm_steps();
    let run = |opt: &mut dyn Optimizer, seed: u64| -> Vec<f32> {
        super::common::run_lm(&w, opt, steps, seed).report.losses
    };
    let seed = exp_seed("fig4", 0);
    let mut o32 = build("adamw32", hp).unwrap();
    let curve32 = run(o32.as_mut(), seed);
    let mut o4 = compressed(hp, QuantPolicy::bit4());
    let curve4 = run(&mut o4, seed);

    let mut table = Table::new(
        "Figure 4 — training loss curve, 32-bit vs 4-bit AdamW \
         (paper: LLaMA-7B/Alpaca; ours: synthetic LM)",
        &["Step", "32-bit AdamW", "4-bit AdamW", "|gap|"],
    );
    let probes = 10usize;
    for k in 0..=probes {
        let i = (k * (steps - 1)) / probes;
        let a = curve32.get(i).copied().unwrap_or(f32::NAN);
        let b = curve4.get(i).copied().unwrap_or(f32::NAN);
        table.row(&[
            format!("{i}"),
            format!("{a:.4}"),
            format!("{b:.4}"),
            format!("{:.4}", (a - b).abs()),
        ]);
    }
    // Tail alignment summary.
    let tail = steps / 5;
    let gap: f64 = curve32
        .iter()
        .rev()
        .take(tail)
        .zip(curve4.iter().rev().take(tail))
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / tail.max(1) as f64;
    let mut summary = Table::new(
        "Figure 4 (summary) — curve alignment",
        &["Metric", "Value"],
    );
    summary.row(&["mean |gap| over final 20% of steps".into(), format!("{gap:.4}")]);
    summary.row(&[
        "final loss 32-bit / 4-bit".into(),
        format!(
            "{:.4} / {:.4}",
            curve32.last().unwrap(),
            curve4.last().unwrap()
        ),
    ]);
    vec![table, summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concentration_detects_axis() {
        let mut rng = Pcg64::seeded(0);
        let mut m = Tensor::randn(&[32, 32], 0.01, &mut rng);
        for j in 0..32 {
            m.set2(5, j, 1.0); // row outlier
        }
        assert!(concentration(&m, 0) > concentration(&m, 1) * 2.0);
    }
}
