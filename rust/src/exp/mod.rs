#![forbid(unsafe_code)]
//! The paper-experiment harness: one module per table/figure of the
//! evaluation section (see DESIGN.md §5 for the index). Each experiment
//! prints the paper's rows and writes `results/<id>.json`.

pub mod common;
pub mod bits;
pub mod figs;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

pub use common::ExpContext;

use crate::util::table::Table;

/// All experiment ids in paper order.
pub fn ids() -> Vec<&'static str> {
    vec![
        "table1", "table2", "table3", "table4", "table5", "table6",
        "fig1", "fig2", "fig3", "fig4", "bits",
    ]
}

/// Run one experiment by id; returns the rendered tables.
pub fn run(id: &str, ctx: &ExpContext) -> Option<String> {
    let tables: Vec<Table> = match id {
        "table1" => table1::run(ctx),
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        "table4" => table4::run(ctx),
        "table5" => table5::run(ctx),
        "table6" => table6::run(ctx),
        "fig1" => figs::fig1(ctx),
        "fig2" => figs::fig2(ctx),
        "fig3" => figs::fig3(ctx),
        "fig4" => figs::fig4(ctx),
        "bits" => bits::run(ctx),
        _ => return None,
    };
    Some(ctx.save(id, &tables))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        let ctx = ExpContext::new(true);
        assert!(run("table99", &ctx).is_none());
    }

    #[test]
    fn table5_runs_instantly() {
        // The analytic experiments must run fast and produce rows.
        let ctx = ExpContext::new(true);
        let out = run("table5", &ctx).unwrap();
        assert!(out.contains("LLaMA-7B"));
        assert!(out.contains("24 GB"));
    }
}
