#![forbid(unsafe_code)]
//! Shared experiment infrastructure: standard workloads (LM, CLS,
//! copy-translation), metric extraction, and result persistence.
//!
//! Scale note (DESIGN.md §3): at toy scale the paper's ≤4096 "don't
//! quantize small tensors" rule would exempt *every* tensor, so the
//! convergence experiments drop it (`min_quant_size = 0`) — the rule is a
//! memory optimization, not a stability requirement. Memory experiments
//! (Tab. 4/5) keep the rule, exactly as implemented.

use crate::data::{copy_task_batch, ClusterData, LmBatch, MarkovCorpus};
use crate::model::{MlpConfig, TransformerConfig};
use crate::optim::lowbit::{CompressedAdamW, QuantPolicy};
use crate::optim::{build, Hyper, Optimizer, Param};
use crate::train::{LrSchedule, MlpEngine, Trainer, TrainReport, TransformerEngine};
use crate::util::json::Json;
use crate::util::rng::{seed_from, Pcg64};
use crate::util::table::Table;

/// Global experiment context.
#[derive(Clone, Debug)]
pub struct ExpContext {
    /// Quick mode: fewer steps/seeds; used by tests and smoke runs.
    pub quick: bool,
    /// Measured-offload mode (`lowbit exp table4 --measured`): run the
    /// executable offload pipeline and report its virtual-time speedups
    /// next to the analytic ones.
    pub measured: bool,
    pub out_dir: String,
}

impl ExpContext {
    pub fn new(quick: bool) -> ExpContext {
        ExpContext {
            quick,
            measured: false,
            out_dir: crate::util::results_dir(),
        }
    }

    /// Enable the measured-offload sub-table of table 4.
    pub fn with_measured(mut self, measured: bool) -> ExpContext {
        self.measured = measured;
        self
    }

    pub fn seeds(&self) -> usize {
        if self.quick {
            2
        } else {
            5
        }
    }

    pub fn lm_steps(&self) -> usize {
        if self.quick {
            80
        } else {
            300
        }
    }

    pub fn cls_steps(&self) -> usize {
        if self.quick {
            120
        } else {
            400
        }
    }

    /// Persist a set of tables under `results/<id>.json` and return the
    /// rendered text.
    pub fn save(&self, id: &str, tables: &[Table]) -> String {
        let mut rendered = String::new();
        let mut arr = Vec::new();
        for t in tables {
            rendered.push_str(&t.render());
            arr.push(t.to_json());
        }
        let mut doc = Json::obj();
        doc.set("experiment", Json::Str(id.to_string()));
        doc.set("quick", Json::Bool(self.quick));
        doc.set("tables", Json::Arr(arr));
        let path = format!("{}/{id}.json", self.out_dir);
        if let Err(e) = crate::util::write_file(&path, &doc.pretty()) {
            crate::util::log(1, "exp", &format!("could not write {path}: {e}"));
        }
        rendered
    }
}

/// The standard small LM workload used by tables 1/2/3/6 and the figures.
#[derive(Clone, Copy)]
pub struct LmWorkload {
    pub cfg: TransformerConfig,
    pub batch: usize,
    pub corpus_seed: u64,
    pub lr: f32,
}

impl LmWorkload {
    pub fn standard() -> LmWorkload {
        LmWorkload {
            cfg: TransformerConfig {
                vocab: 256,
                d_model: 64,
                n_heads: 4,
                d_ff: 128,
                n_layers: 2,
                max_seq: 24,
            },
            batch: 8,
            corpus_seed: 1234,
            lr: 2e-3,
        }
    }

    /// Scaled family used by the Tab. 3 reproduction. Smaller vocab than
    /// `standard()` so each scale trains to a meaningful accuracy within
    /// the experiment budget.
    pub fn scaled(depth: usize, width: usize) -> LmWorkload {
        let mut w = LmWorkload::standard();
        w.cfg = TransformerConfig {
            vocab: 64,
            d_model: width,
            n_heads: (width / 16).max(1),
            d_ff: width * 2,
            n_layers: depth,
            max_seq: 24,
        };
        w
    }
}

/// Outcome of one LM run with evaluation.
pub struct LmOutcome {
    pub report: TrainReport,
    /// Held-out next-token top-1 accuracy (the QA/F1 surrogate).
    pub eval_acc: f64,
    /// Held-out mean loss.
    pub eval_loss: f64,
    pub params: Vec<Param>,
}

/// Train an LM workload with the given optimizer; evaluate on held-out
/// batches.
pub fn run_lm(
    w: &LmWorkload,
    opt: &mut dyn Optimizer,
    steps: usize,
    seed: u64,
) -> LmOutcome {
    let engine = TransformerEngine::new(w.cfg);
    let corpus = MarkovCorpus::new(w.cfg.vocab, w.corpus_seed);
    let mut init_rng = Pcg64::new(seed, 11);
    let mut params = w.cfg.init_params(&mut init_rng);
    let mut data_rng = Pcg64::new(seed, 12);
    let trainer = Trainer::new(
        steps,
        LrSchedule::LinearWarmupDecay {
            peak: w.lr,
            warmup: steps / 10 + 1,
            total: steps,
        },
    );
    let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    let report = trainer.run(&mut params, opt, &mut engine_fn, |_| {
        corpus.sample(w.batch, w.cfg.max_seq, &mut data_rng)
    });
    let (eval_loss, eval_acc) = lm_eval(&engine, &params, &corpus, w, seed ^ 0xEEEE, 6);
    LmOutcome {
        report,
        eval_acc,
        eval_loss,
        params,
    }
}

/// Held-out evaluation: mean loss + next-token top-1 accuracy.
pub fn lm_eval(
    engine: &TransformerEngine,
    params: &[Param],
    corpus: &MarkovCorpus,
    w: &LmWorkload,
    seed: u64,
    batches: usize,
) -> (f64, f64) {
    let mut rng = Pcg64::new(seed, 99);
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..batches {
        let batch = corpus.sample(w.batch, w.cfg.max_seq, &mut rng);
        loss_sum += engine.loss(params, &batch) as f64;
        let (c, t) = next_token_accuracy(engine, params, &batch);
        correct += c;
        total += t;
    }
    (loss_sum / batches as f64, correct as f64 / total as f64)
}

/// Top-1 next-token accuracy of a trained LM on one batch.
pub fn next_token_accuracy(
    engine: &TransformerEngine,
    params: &[Param],
    batch: &LmBatch,
) -> (usize, usize) {
    // Greedy: for each position, rerun loss with logits argmax — the
    // builtin engine exposes loss only, so take a cheap path: compare
    // per-position losses is overkill; instead reuse the forward pass by
    // scoring each candidate? Too slow. We re-implement a light forward
    // via the engine's loss on crafted batches would be wasteful, so the
    // engine provides logits through loss_and_grads' softmax — simplest
    // correct approach: use a 1-step readout below.
    engine.next_token_accuracy(params, batch)
}

/// Build a `CompressedAdamW` with the convergence-experiment policy
/// adjustments (min_quant_size = 0).
pub fn compressed(hp: Hyper, mut policy: QuantPolicy) -> CompressedAdamW {
    policy.min_quant_size = 0;
    CompressedAdamW::new(hp, policy)
}

/// Build a preset optimizer with experiment-scale adjustments applied.
pub fn preset_optimizer(name: &str, hp: Hyper) -> Box<dyn Optimizer> {
    match name {
        "adamw8" => Box::new(compressed(hp, QuantPolicy::bit8())),
        "adamw4" => Box::new(compressed(hp, QuantPolicy::bit4())),
        "factor4" => Box::new(compressed(hp, QuantPolicy::bit4().factored())),
        other => build(other, hp).unwrap_or_else(|| panic!("unknown preset {other}")),
    }
}

/// Classification workload (CLS/NLU surrogates).
pub struct ClsOutcome {
    pub report: TrainReport,
    pub accuracy: f64,
}

pub fn run_cls(
    cfg: MlpConfig,
    data_seed: u64,
    opt: &mut dyn Optimizer,
    steps: usize,
    seed: u64,
) -> ClsOutcome {
    run_cls_spread(cfg, data_seed, opt, steps, seed, 2.0)
}

/// `spread` < 2.0 makes the task harder (class means closer together).
pub fn run_cls_spread(
    cfg: MlpConfig,
    data_seed: u64,
    opt: &mut dyn Optimizer,
    steps: usize,
    seed: u64,
    spread: f32,
) -> ClsOutcome {
    let engine = MlpEngine::new(cfg);
    let data = ClusterData::with_spread(cfg.d_in, cfg.n_classes, data_seed, spread);
    let mut init_rng = Pcg64::new(seed, 21);
    let mut params = cfg.init_params(&mut init_rng);
    let mut data_rng = Pcg64::new(seed, 22);
    let trainer = Trainer::new(steps, LrSchedule::Constant(3e-3));
    let mut engine_fn =
        |p: &[Param], b: &crate::data::ClsBatch| engine.loss_and_grads(p, b);
    let report = trainer.run(&mut params, opt, &mut engine_fn, |_| {
        data.sample(32, &mut data_rng)
    });
    let mut eval_rng = Pcg64::new(seed ^ 0xAAAA, 23);
    let test = data.sample(600, &mut eval_rng);
    let accuracy = engine.accuracy(&params, &test);
    ClsOutcome { report, accuracy }
}

/// Copy-translation workload (MT surrogate): returns accuracy on the
/// "translated" second half.
pub fn run_copy_task(opt: &mut dyn Optimizer, steps: usize, seed: u64) -> (TrainReport, f64) {
    let cfg = TransformerConfig {
        vocab: 64,
        d_model: 64,
        n_heads: 4,
        d_ff: 128,
        n_layers: 2,
        max_seq: 16,
    };
    let engine = TransformerEngine::new(cfg);
    let mut init_rng = Pcg64::new(seed, 31);
    let mut params = cfg.init_params(&mut init_rng);
    let mut data_rng = Pcg64::new(seed, 32);
    let task_seed = 777u64;
    let trainer = Trainer::new(
        steps,
        LrSchedule::LinearWarmupDecay {
            peak: 3e-3,
            warmup: steps / 10 + 1,
            total: steps,
        },
    );
    let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    let report = trainer.run(&mut params, opt, &mut engine_fn, |_| {
        copy_task_batch(cfg.vocab, 8, cfg.max_seq, task_seed, &mut data_rng)
    });
    // Accuracy on the second (translated) half of held-out sequences.
    let mut eval_rng = Pcg64::new(seed ^ 0x7777, 33);
    let batch = copy_task_batch(cfg.vocab, 16, cfg.max_seq, task_seed, &mut eval_rng);
    let acc = engine.second_half_accuracy(&params, &batch);
    (report, acc)
}

/// Mean ± std cell over per-seed metric values, flagging divergence.
pub fn metric_cell(values: &[f64], decimals: usize) -> String {
    let s = crate::util::stats::summarize(values);
    crate::util::table::pm(s.mean(), s.std(), decimals)
}

/// Derive per-(row, seed) seeds deterministically from a label.
pub fn exp_seed(label: &str, seed_idx: usize) -> u64 {
    seed_from(&format!("{label}/seed{seed_idx}"))
}
