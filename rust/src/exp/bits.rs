#![forbid(unsafe_code)]
//! Extension experiment (paper §7 future-work direction): how low can the
//! bitwidth go? Sweeps 2..8 bits for both moments with the paper's final
//! scheme (m: B128/DE, v: Rank-1-or-B128/Linear) on the standard LM
//! workload. The paper stops at 4; this shows where the cliff is.

use super::common::{compressed, exp_seed, metric_cell, run_lm, ExpContext, LmWorkload};
use crate::optim::lowbit::QuantPolicy;
use crate::optim::Hyper;
use crate::quant::{MapKind, NormKind, Quantizer};
use crate::util::table::Table;

fn policy_for_bits(bits: u8) -> QuantPolicy {
    // Signed DE needs >= 3 bits; at 2 bits fall back to signed linear.
    let m_map = if bits >= 3 { MapKind::DynExp } else { MapKind::Linear };
    let m = Quantizer::new(NormKind::Block(128), m_map, bits, true);
    let v = Quantizer::new(NormKind::Rank1, MapKind::Linear, bits, false);
    QuantPolicy::bit4().with_m(Some(m)).with_v(Some(v))
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let w = LmWorkload::standard();
    let hp = Hyper::default();
    let mut table = Table::new(
        "Bitwidth sweep (extension) — paper scheme at 2..8 bits \
         (score = held-out next-token acc %)",
        &["Bits", "Unstable(%)", "Score", "State bytes/param"],
    );
    let steps = ctx.lm_steps();
    for bits in [2u8, 3, 4, 5, 6, 8] {
        let mut scores = Vec::new();
        let mut unstable = 0usize;
        let mut state_bytes = 0usize;
        let mut n_params = 0usize;
        for s in 0..ctx.seeds() {
            let mut opt = compressed(hp, policy_for_bits(bits));
            let out = run_lm(&w, &mut opt, steps, exp_seed(&format!("bits/{bits}"), s));
            state_bytes = out.report.state_bytes;
            n_params = out.params.iter().map(|p| p.tensor.numel()).sum();
            if out.report.diverged {
                unstable += 1;
            } else {
                scores.push(out.eval_acc * 100.0);
            }
        }
        let score = if scores.is_empty() {
            "diverged".to_string()
        } else {
            metric_cell(&scores, 1)
        };
        table.row(&[
            format!("{bits}"),
            format!("{:.0}", 100.0 * unstable as f64 / ctx.seeds() as f64),
            score,
            format!("{:.2}", state_bytes as f64 / n_params as f64),
        ]);
    }
    vec![table]
}
