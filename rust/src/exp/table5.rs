#![forbid(unsafe_code)]
//! Tab. 5 reproduction: largest trainable model under a memory budget
//! (batch 1, max length 512 — the paper's setup), via the exact state
//! accounting + activation model. Expected shape: 4-bit AdamW unlocks
//! ~4x-larger OPT models and fits LLaMA-7B in 80 GB.

use super::common::ExpContext;
use crate::memory::{largest_trainable, training_bytes, StatePreset, TrainSetup, GB};
use crate::model::{llama_family, opt_family};
use crate::util::table::Table;

pub fn run(_ctx: &ExpContext) -> Vec<Table> {
    let setup = TrainSetup { batch: 1, seq: 512 };
    let mut table = Table::new(
        "Table 5 — largest fine-tunable model under a memory budget \
         (batch 1, seq 512)",
        &["GPU Mem", "32-bit AdamW", "4-bit AdamW"],
    );
    let opt = opt_family();
    for budget_gb in [24u64, 48, 80] {
        let b = budget_gb * GB;
        let best32 = largest_trainable(&opt, StatePreset::AdamW32, setup, b).unwrap_or("-");
        let best4 = largest_trainable(&opt, StatePreset::AdamW4, setup, b).unwrap_or("-");
        table.row(&[format!("{budget_gb} GB"), best32.to_string(), best4.to_string()]);
    }
    // LLaMA-7B at 80 GB — the paper's headline row.
    let llama = &llama_family()[0];
    let fits32 = training_bytes(&llama.cfg, StatePreset::AdamW32, setup) <= 80 * GB;
    let fits4 = training_bytes(&llama.cfg, StatePreset::AdamW4, setup) <= 80 * GB;
    table.row(&[
        "80 GB".to_string(),
        if fits32 { "LLaMA-7B" } else { "-" }.to_string(),
        if fits4 { "LLaMA-7B" } else { "-" }.to_string(),
    ]);

    // Supplementary: the raw footprints behind the search.
    let mut detail = Table::new(
        "Table 5 (detail) — modeled training footprint per model",
        &["Model", "Params", "32-bit AdamW", "4-bit AdamW", "4-bit Factor"],
    );
    for m in opt.iter().chain(llama_family().iter()) {
        let gb = |p| training_bytes(&m.cfg, p, setup) as f64 / GB as f64;
        detail.row(&[
            m.name.to_string(),
            format!("{:.2}B", m.cfg.n_params() as f64 / 1e9),
            format!("{:.1} GB", gb(StatePreset::AdamW32)),
            format!("{:.1} GB", gb(StatePreset::AdamW4)),
            format!("{:.1} GB", gb(StatePreset::Factor4)),
        ]);
    }
    vec![table, detail]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_rows_have_expected_shape() {
        let ctx = ExpContext::new(true);
        let tables = run(&ctx);
        let t = &tables[0];
        // At 24 GB the 4-bit column must name a strictly larger OPT model.
        let row24 = &t.rows[0];
        assert_eq!(row24[0], "24 GB");
        assert_ne!(row24[1], row24[2]);
        // LLaMA-7B row: "-" under 32-bit, LLaMA-7B under 4-bit.
        let llama_row = t.rows.last().unwrap();
        assert_eq!(llama_row[1], "-");
        assert_eq!(llama_row[2], "LLaMA-7B");
    }
}
