#![forbid(unsafe_code)]
//! Tab. 3 reproduction: "instruction tuning" across model scales.
//!
//! Paper: LLaMA-7/13/33B fine-tuned on Alpaca, evaluated on MMLU +
//! commonsense suites. Ours: three LM scales are *pretrained* on a base
//! corpus, then fine-tuned on a second (shifted) corpus with 32-bit vs
//! 4-bit AdamW; rows also report the un-finetuned "Original" model.
//! Metrics: accuracy on the fine-tune distribution (the "MMLU" column
//! surrogate) and on the base distribution (checking the finetune did not
//! destroy pretrained capability — the commonsense surrogate).
//! Expected shape: 4-bit ≈ 32-bit at every scale, both beat Original on
//! the tuned distribution.

use super::common::{compressed, exp_seed, lm_eval, ExpContext, LmWorkload};
use crate::data::{LmBatch, MarkovCorpus};
use crate::optim::lowbit::QuantPolicy;
use crate::optim::{build, Hyper, Optimizer, Param};
use crate::train::{LrSchedule, Trainer, TransformerEngine};
use crate::util::rng::Pcg64;
use crate::util::table::Table;

struct Scale {
    name: &'static str,
    depth: usize,
    width: usize,
}

fn scales(quick: bool) -> Vec<Scale> {
    if quick {
        vec![
            Scale { name: "LM-tiny", depth: 1, width: 32 },
            Scale { name: "LM-small", depth: 2, width: 48 },
        ]
    } else {
        vec![
            Scale { name: "LM-tiny", depth: 1, width: 32 },
            Scale { name: "LM-small", depth: 2, width: 64 },
            Scale { name: "LM-base", depth: 3, width: 96 },
        ]
    }
}

fn train(
    w: &LmWorkload,
    params: &mut Vec<Param>,
    corpus: &MarkovCorpus,
    opt: &mut dyn Optimizer,
    steps: usize,
    lr: f32,
    seed: u64,
) {
    let engine = TransformerEngine::new(w.cfg);
    let mut data_rng = Pcg64::new(seed, 41);
    let trainer = Trainer::new(
        steps,
        LrSchedule::LinearWarmupDecay {
            peak: lr,
            warmup: steps / 10 + 1,
            total: steps,
        },
    );
    let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    trainer.run(params, opt, &mut engine_fn, |_| {
        corpus.sample(w.batch, w.cfg.max_seq, &mut data_rng)
    });
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let hp = Hyper::default();
    let mut table = Table::new(
        "Table 3 — fine-tuning across scales (Tuned-acc %: fine-tune \
         distribution; Base-acc %: pretraining distribution retained)",
        &["Model", "Optimizer", "Tuned acc", "Base acc"],
    );
    // Tab. 3 needs actually-converged pretraining to show "finetune
    // improves tuned-distribution accuracy without destroying base
    // capability"; it gets a larger step budget than the ablations.
    let steps_pre = ctx.lm_steps() * 3;
    let steps_ft = ctx.lm_steps();
    for scale in scales(ctx.quick) {
        let mut w = LmWorkload::scaled(scale.depth, scale.width);
        let base_corpus = MarkovCorpus::new(w.cfg.vocab, 1000);
        let tune_corpus = MarkovCorpus::new(w.cfg.vocab, 2000);
        let engine = TransformerEngine::new(w.cfg);
        let seed = exp_seed(&format!("table3/{}", scale.name), 0);
        // Pretrain once with 32-bit AdamW.
        let mut rng = Pcg64::new(seed, 40);
        let mut pre_params = w.cfg.init_params(&mut rng);
        let mut opt = build("adamw32", hp).unwrap();
        train(&w, &mut pre_params, &base_corpus, opt.as_mut(), steps_pre, w.lr, seed);

        w.corpus_seed = 2000;
        let eval_tuned = |params: &[Param]| {
            lm_eval(&engine, params, &tune_corpus, &w, seed ^ 0xF1, 5).1 * 100.0
        };
        let eval_base = |params: &[Param]| {
            lm_eval(&engine, params, &base_corpus, &w, seed ^ 0xF2, 5).1 * 100.0
        };

        // Original (no fine-tuning).
        table.row(&[
            scale.name.to_string(),
            "Original".to_string(),
            format!("{:.1}", eval_tuned(&pre_params)),
            format!("{:.1}", eval_base(&pre_params)),
        ]);
        // Fine-tune with 32-bit vs 4-bit AdamW from the same checkpoint.
        for (label, use4) in [("32-bit AdamW", false), ("4-bit AdamW", true)] {
            let mut params = pre_params.clone();
            let mut opt: Box<dyn Optimizer> = if use4 {
                Box::new(compressed(hp, QuantPolicy::bit4()))
            } else {
                build("adamw32", hp).unwrap()
            };
            train(
                &w,
                &mut params,
                &tune_corpus,
                opt.as_mut(),
                steps_ft,
                w.lr * 0.5,
                seed ^ 0xBEEF,
            );
            table.row(&[
                scale.name.to_string(),
                label.to_string(),
                format!("{:.1}", eval_tuned(&params)),
                format!("{:.1}", eval_base(&params)),
            ]);
        }
    }
    vec![table]
}
