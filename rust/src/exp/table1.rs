#![forbid(unsafe_code)]
//! Tab. 1 reproduction: ablation of second-moment quantization schemes.
//!
//! Paper setting: GPT-2 Medium on E2E-NLG, BLEU + Unstable%. Ours: the
//! standard synthetic LM workload; "score" is held-out next-token
//! accuracy (higher = better, BLEU surrogate) and Unstable% is the
//! fraction of seeds whose run diverged (same definition as the paper).
//! The first moment is fixed at 4-bit B2048/DE (the "barely 4-bit" of the
//! paper's first row); rows vary only the second-moment scheme.
//!
//! Expected shape: DE rows (zero point) are unstable or degraded; the
//! stable-embedding mitigation helps but does not fix non-embedding
//! layers; DE-0 / Linear rows are stable; Rank-1/Linear is best.

use super::common::{compressed, exp_seed, metric_cell, run_lm, ExpContext, LmWorkload};
use crate::optim::lowbit::QuantPolicy;
use crate::optim::Hyper;
use crate::quant::{MapKind, NormKind, Quantizer};
use crate::util::table::Table;

struct Row {
    norm: &'static str,
    map: &'static str,
    stable_embed: bool,
    factored: bool,
    sr: bool,
}

fn rows() -> Vec<Row> {
    let r = |norm, map, stable_embed, factored, sr| Row {
        norm,
        map,
        stable_embed,
        factored,
        sr,
    };
    vec![
        r("B2048", "DE", false, false, false),
        r("B2048", "DE", true, false, false),
        r("B128", "DE", false, false, false),
        r("B128", "DE", false, false, true),
        r("B128", "DE", true, false, false),
        r("B2048", "DE-0", false, false, false),
        r("B2048", "DE-0", true, false, false),
        r("B128", "DE-0", false, false, false),
        r("Rank-1", "DE-0", false, false, false),
        r("Rank-1", "Linear", false, false, false),
        r("Rank-1", "Linear", false, true, false),
    ]
}

fn policy_for(row: &Row) -> QuantPolicy {
    let norm = NormKind::parse(row.norm).expect("norm");
    let map = MapKind::parse(row.map).expect("map");
    let mut v = Quantizer::new(norm, map, 4, false);
    if row.sr {
        v = v.with_stochastic(true);
    }
    // First moment fixed: 4-bit B2048/DE (paper's baseline row).
    let m = Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true);
    let mut p = QuantPolicy::bit4()
        .with_m(Some(m))
        .with_v(Some(v))
        .with_skip_embedding(row.stable_embed);
    if row.factored {
        p = p.factored();
    }
    p
}

pub fn run(ctx: &ExpContext) -> Vec<Table> {
    let mut w = LmWorkload::standard();
    // More aggressive LR than the other experiments: the zero-point
    // instability the paper reports at GPT-2-M scale only surfaces at toy
    // scale when updates are large enough for a v->0 block to kick the
    // weights hard (see DESIGN.md §3).
    w.lr = 6e-3;
    let hp = Hyper::default();
    let mut table = Table::new(
        "Table 1 — second-moment quantizer ablation (synthetic LM; \
         score = held-out next-token acc %, paper metric: BLEU)",
        &["Normalization", "Mapping", "StableEmb", "Factorized", "Unstable(%)", "Score"],
    );
    let steps = ctx.lm_steps();
    for row in rows() {
        let label = format!(
            "table1/{}-{}-se{}-f{}-sr{}",
            row.norm, row.map, row.stable_embed, row.factored, row.sr
        );
        let mut scores = Vec::new();
        let mut unstable = 0usize;
        for s in 0..ctx.seeds() {
            let mut opt = compressed(hp, policy_for(&row));
            let out = run_lm(&w, &mut opt, steps, exp_seed(&label, s));
            if out.report.diverged {
                unstable += 1;
            } else {
                scores.push(out.eval_acc * 100.0);
            }
        }
        let unstable_pct = 100.0 * unstable as f64 / ctx.seeds() as f64;
        let score = if scores.is_empty() {
            "diverged".to_string()
        } else {
            metric_cell(&scores, 1)
        };
        table.row(&[
            row.norm.to_string(),
            if row.sr { format!("{}+SR", row.map) } else { row.map.to_string() },
            if row.stable_embed { "Yes" } else { "No" }.to_string(),
            if row.factored { "Yes" } else { "No" }.to_string(),
            format!("{unstable_pct:.0}"),
            score,
        ]);
    }
    vec![table]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policies_construct_for_all_rows() {
        for row in rows() {
            let p = policy_for(&row);
            assert_eq!(p.factor_v, row.factored);
            assert_eq!(p.skip_embedding, row.stable_embed);
            let v = p.v_quant.unwrap();
            assert_eq!(v.norm.name(), row.norm);
            assert_eq!(v.map.name(), row.map);
            assert_eq!(v.stochastic, row.sr);
        }
    }
}
