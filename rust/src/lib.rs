//! `lowbit-opt` — a reproduction of *Memory Efficient Optimizers with
//! 4-bit States* (Li, Chen & Zhu, NeurIPS 2023) as a three-layer
//! Rust + JAX + Pallas training framework.
//!
//! Layer map (see DESIGN.md):
//! * L1/L2 live in `python/compile/` (Pallas kernels + JAX graphs, AOT
//!   lowered to HLO text at build time).
//! * L3 is this crate: quantization engine ([`quant`]), the
//!   shard-parallel optimizer step engine ([`engine`]), optimizer zoo
//!   ([`optim`]), builtin training engines ([`train`]), synthetic data
//!   ([`data`]), the PJRT runtime ([`runtime`]) that executes the AOT
//!   artifacts, memory accounting ([`memory`]), the offload tier —
//!   analytic oracle + executable host-state pipeline ([`offload`]) —
//!   the telemetry/observability layer ([`obs`]: span tracing behind
//!   the `trace` feature, quant-quality metrics, unified step reports),
//!   the deterministic fault-injection and integrity layer ([`fault`]:
//!   seeded fault plans, CRC-32 transfer/section checksums), and the
//!   paper-experiment harness ([`exp`]).
//!
//! # The unsafe boundary
//!
//! `unsafe` is confined to an explicit allowlist of modules (the engine
//! executors, the offload staging layer, checkpoint byte packing, and
//! the AVX2 quant-kernel tier `quant/kernels/avx2.rs` — SIMD intrinsics
//! behind safe wrappers, runtime-dispatched and bit-identical to the
//! scalar tier); every other module carries `#![forbid(unsafe_code)]`.
//! The allowlist, SAFETY-comment coverage and the stamps are enforced
//! mechanically by `rust/src/bin/lint.rs` (tier-1 test `unsafe_lint`), and the
//! engine's disjointness contract is checked at runtime by the
//! aliasing auditor (`--features audit`, see `engine::audit`).

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod util;
pub mod tensor;
pub mod quant;
pub mod fault;
pub mod engine;
pub mod optim;
pub mod model;
pub mod data;
pub mod train;
pub mod runtime;
pub mod memory;
pub mod obs;
pub mod offload;
pub mod config;
pub mod exp;
