#![forbid(unsafe_code)]
//! Seeded synthetic workloads standing in for the paper's datasets (see
//! DESIGN.md §3 for the substitution rationale). Three generators:
//!
//! * [`MarkovCorpus`] — a zipfian-unigram / sparse-Markov token stream.
//!   Learnable next-token structure; drives the NLG/QA/instruction-tuning
//!   surrogates and the end-to-end LM example. Anisotropic token
//!   frequencies are what give moment tensors their row/column outliers.
//! * [`ClusterData`] — anisotropic Gaussian blobs for classification (the
//!   CLS/NLU surrogate).
//! * [`copy_task_batch`] — a copy/translation sequence task (MT surrogate):
//!   the second half of each sequence deterministically transforms the
//!   first half, so a causal LM must learn an input-dependent mapping.

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// A batch of token sequences: `tokens[b][t]`, targets are the next token.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub tokens: Vec<Vec<u32>>, // [batch][seq+1]
}

impl LmBatch {
    pub fn batch_size(&self) -> usize {
        self.tokens.len()
    }

    pub fn seq_len(&self) -> usize {
        self.tokens.first().map_or(0, |t| t.len() - 1)
    }
}

/// A classification batch.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    pub x: Tensor,      // [batch, d_in]
    pub y: Vec<usize>,  // [batch]
}

/// Zipfian-unigram, sparse-Markov synthetic corpus.
///
/// Token `t+1` is drawn from a per-token sparse transition row with
/// probability `markov_weight`, otherwise from a global zipfian unigram.
/// The chain is fixed at construction, so the distribution is stationary
/// and a trained LM's loss has a well-defined floor.
pub struct MarkovCorpus {
    pub vocab: usize,
    markov_weight: f64,
    /// Per-token successor candidates (sparse transition support).
    successors: Vec<Vec<u32>>,
    zipf_weights: Vec<f64>,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> MarkovCorpus {
        let mut rng = Pcg64::new(seed, 101);
        let branch = 4usize;
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab) as u32).collect())
            .collect();
        // Zipf(1.0) unigram.
        let zipf_weights = (0..vocab).map(|i| 1.0 / (i + 1) as f64).collect();
        MarkovCorpus {
            vocab,
            markov_weight: 0.75,
            successors,
            zipf_weights,
        }
    }

    fn next_token(&self, cur: u32, rng: &mut Pcg64) -> u32 {
        if rng.next_f64() < self.markov_weight {
            let succ = &self.successors[cur as usize];
            succ[rng.below(succ.len())]
        } else {
            rng.categorical(&self.zipf_weights) as u32
        }
    }

    /// Sample a batch of `batch` sequences of `seq` tokens (plus one for
    /// the shifted target).
    pub fn sample(&self, batch: usize, seq: usize, rng: &mut Pcg64) -> LmBatch {
        let tokens = (0..batch)
            .map(|_| {
                let mut s = Vec::with_capacity(seq + 1);
                let mut cur = rng.categorical(&self.zipf_weights) as u32;
                s.push(cur);
                for _ in 0..seq {
                    cur = self.next_token(cur, rng);
                    s.push(cur);
                }
                s
            })
            .collect();
        LmBatch { tokens }
    }

    /// Entropy floor estimate (nats/token) via Monte-Carlo — a trained LM
    /// cannot do better than this; used to sanity-check convergence.
    pub fn entropy_floor(&self, samples: usize, rng: &mut Pcg64) -> f64 {
        // For each sampled current token, the next-token distribution is
        // markov_weight * uniform(successors) + (1-w) * zipf.
        let zipf_total: f64 = self.zipf_weights.iter().sum();
        let mut acc = 0.0;
        for _ in 0..samples {
            let cur = rng.categorical(&self.zipf_weights);
            let succ = &self.successors[cur];
            // Entropy of the mixture, summed over support (approximate:
            // zipf mass spread over vocab, successors get spikes).
            let mut h = 0.0;
            for tok in 0..self.vocab {
                let spike = succ.iter().filter(|&&s| s as usize == tok).count() as f64
                    / succ.len() as f64;
                let p = self.markov_weight * spike
                    + (1.0 - self.markov_weight) * self.zipf_weights[tok] / zipf_total;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            acc += h;
        }
        acc / samples as f64
    }
}

/// Anisotropic Gaussian blobs: class `c` has a random mean and a shared
/// diagonal covariance with a few high-variance directions (which is what
/// pushes outlier structure into first-layer moments).
pub struct ClusterData {
    pub d_in: usize,
    pub n_classes: usize,
    means: Vec<Vec<f32>>,
    scales: Vec<f32>,
}

impl ClusterData {
    pub fn new(d_in: usize, n_classes: usize, seed: u64) -> ClusterData {
        Self::with_spread(d_in, n_classes, seed, 2.0)
    }

    /// `mean_scale` controls class separation (smaller = harder task).
    pub fn with_spread(d_in: usize, n_classes: usize, seed: u64, mean_scale: f32) -> ClusterData {
        let mut rng = Pcg64::new(seed, 202);
        let means = (0..n_classes)
            .map(|_| (0..d_in).map(|_| rng.normal() * mean_scale).collect())
            .collect();
        // A few coordinates get 8x the noise scale.
        let scales = (0..d_in)
            .map(|_| if rng.next_f64() < 0.1 { 8.0 } else { 1.0 })
            .collect();
        ClusterData {
            d_in,
            n_classes,
            means,
            scales,
        }
    }

    pub fn sample(&self, batch: usize, rng: &mut Pcg64) -> ClsBatch {
        let mut x = Tensor::zeros(&[batch, self.d_in]);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let c = rng.below(self.n_classes);
            y.push(c);
            for j in 0..self.d_in {
                x.data[b * self.d_in + j] =
                    self.means[c][j] + rng.normal() * self.scales[j];
            }
        }
        ClsBatch { x, y }
    }

    /// Bayes-ish reference accuracy via nearest-mean classification on a
    /// fresh sample (upper bound proxy for learned accuracy).
    pub fn nearest_mean_accuracy(&self, n: usize, rng: &mut Pcg64) -> f64 {
        let batch = self.sample(n, rng);
        let mut correct = 0usize;
        for b in 0..n {
            let mut best = 0;
            let mut bestd = f64::INFINITY;
            for (c, mean) in self.means.iter().enumerate() {
                let mut d = 0.0f64;
                for j in 0..self.d_in {
                    let diff = (batch.x.data[b * self.d_in + j] - mean[j]) as f64
                        / self.scales[j] as f64;
                    d += diff * diff;
                }
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            if best == batch.y[b] {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

/// Copy/translation task (MT surrogate): tokens `[0, T/2)` are random from
/// the source half of the vocab; tokens `[T/2, T)` are `f(token[t - T/2])`
/// where `f` is a fixed random bijection into the target half. A causal LM
/// must learn `f` to predict the second half.
pub fn copy_task_batch(
    vocab: usize,
    batch: usize,
    seq: usize,
    seed: u64,
    rng: &mut Pcg64,
) -> LmBatch {
    assert!(vocab >= 4 && seq >= 2);
    let half_v = vocab / 2;
    // Fixed bijection derived from seed.
    let mut perm: Vec<u32> = (0..half_v as u32).collect();
    let mut prng = Pcg64::new(seed, 303);
    prng.shuffle(&mut perm);
    let half_t = seq / 2;
    let tokens = (0..batch)
        .map(|_| {
            let mut s = Vec::with_capacity(seq + 1);
            for _ in 0..half_t {
                s.push(rng.below(half_v) as u32);
            }
            for t in 0..(seq + 1 - half_t) {
                let src = s[t % half_t];
                s.push(half_v as u32 + perm[src as usize]);
            }
            s
        })
        .collect();
    LmBatch { tokens }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_deterministic_given_seed() {
        let c1 = MarkovCorpus::new(64, 9);
        let c2 = MarkovCorpus::new(64, 9);
        let mut r1 = Pcg64::seeded(1);
        let mut r2 = Pcg64::seeded(1);
        assert_eq!(c1.sample(2, 8, &mut r1).tokens, c2.sample(2, 8, &mut r2).tokens);
    }

    #[test]
    fn markov_tokens_in_vocab() {
        let c = MarkovCorpus::new(32, 3);
        let mut rng = Pcg64::seeded(0);
        let b = c.sample(4, 16, &mut rng);
        assert_eq!(b.batch_size(), 4);
        assert_eq!(b.seq_len(), 16);
        for s in &b.tokens {
            assert_eq!(s.len(), 17);
            assert!(s.iter().all(|&t| (t as usize) < 32));
        }
    }

    #[test]
    fn markov_entropy_below_uniform() {
        let c = MarkovCorpus::new(64, 5);
        let mut rng = Pcg64::seeded(0);
        let h = c.entropy_floor(200, &mut rng);
        let uniform = (64f64).ln();
        assert!(h < uniform * 0.8, "floor {h} vs uniform {uniform}");
        assert!(h > 0.5);
    }

    #[test]
    fn clusters_learnable() {
        let d = ClusterData::new(16, 4, 7);
        let mut rng = Pcg64::seeded(0);
        let acc = d.nearest_mean_accuracy(500, &mut rng);
        assert!(acc > 0.5, "nearest-mean acc {acc} should beat chance 0.25");
        let b = d.sample(8, &mut rng);
        assert_eq!(b.x.shape, vec![8, 16]);
        assert!(b.y.iter().all(|&y| y < 4));
    }

    #[test]
    fn copy_task_is_deterministic_mapping() {
        let mut rng = Pcg64::seeded(0);
        let b = copy_task_batch(32, 4, 16, 11, &mut rng);
        for s in &b.tokens {
            // Second half must be a function of the first half: same source
            // token -> same target token.
            let half = 8;
            for t in 0..half.min(s.len() - half) {
                for u in 0..half.min(s.len() - half) {
                    if s[t] == s[u] {
                        assert_eq!(s[half + t], s[half + u]);
                    }
                }
            }
            // Halves use disjoint vocab ranges.
            assert!(s[..half].iter().all(|&t| t < 16));
            assert!(s[half..].iter().all(|&t| t >= 16 && t < 32));
        }
    }
}
