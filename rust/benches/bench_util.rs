//! Minimal benchmarking harness shared by the `[[bench]]` binaries.
//! (The offline crate set has no `criterion`; `cargo bench` runs these as
//! `harness = false` executables.)
//!
//! Method: warm up, then run timed batches until both a minimum wall time
//! and a minimum iteration count are reached; report mean / p50 / p95 per
//! iteration and derived throughput.

use lowbit_opt::util::json::Json;
use std::time::Instant;

/// Append one run object to a JSON file holding an array of runs — the
/// shared convention of the BENCH_*.json perf trajectories: a legacy
/// single-object file is wrapped into an array, and an unparseable file
/// (e.g. truncated by a killed bench run) is preserved under
/// `<path>.bak` before starting a fresh array.
///
/// (`allow(dead_code)`: each bench binary compiles its own copy of this
/// module, and only the JSON-emitting benches call this.)
#[allow(dead_code)]
pub fn append_bench_run(path: &str, run: Json) {
    let mut runs = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Arr(v)) => v,
            Ok(obj @ Json::Obj(_)) => vec![obj],
            _ => {
                let bak = format!("{path}.bak");
                eprintln!("warning: {path} is not valid JSON; saving it to {bak}");
                let _ = std::fs::rename(path, &bak);
                Vec::new()
            }
        },
        Err(_) => Vec::new(),
    };
    runs.push(run);
    lowbit_opt::util::write_file(path, &Json::Arr(runs).pretty()).expect("write bench json");
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, bytes_per_iter: Option<u64>) -> String {
        let mut s = format!(
            "{:<44} {:>10.2} us/iter  p50 {:>8.2}  p95 {:>8.2}  ({} iters)",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.iters
        );
        if let Some(b) = bytes_per_iter {
            let gbs = b as f64 / self.mean_ns; // bytes/ns == GB/s
            s.push_str(&format!("  {:>7.2} GB/s", gbs));
        }
        s
    }
}

/// Benchmark a closure. `min_seconds` of measurement after 3 warmup calls.
pub fn bench(name: &str, min_seconds: f64, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..3 {
        f();
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_seconds || samples_ns.len() < 10 {
        let t = Instant::now();
        f();
        samples_ns.push(t.elapsed().as_nanos() as f64);
        if samples_ns.len() > 100_000 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
