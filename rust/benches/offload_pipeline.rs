//! Bench: the executable offload pipeline on a ≥16M-parameter synthetic
//! model — wall time of the staged schedule (real memcpy + compute on
//! the worker pool) next to the *virtual* step time and overlap fraction
//! the ThrottledLink accounts, across threads 1/2/4/8 × prefetch depth
//! 1/2/4 for the adamw32 and adamw4 presets.
//!
//! Flags:
//!   --smoke        short measurement windows (CI)
//!   --json PATH    append the run to PATH (BENCH_offload.json keeps one
//!                  entry per CI run, so the offload perf trajectory
//!                  stays visible across PRs)

mod bench_util;

use bench_util::{append_bench_run, bench, section};
use lowbit_opt::engine::{active_sched, SchedStats};
use lowbit_opt::obs::report::{FaultCounters, SpanSummary};
use lowbit_opt::offload::{LinkModel, OffloadConfig, OffloadReport};
use lowbit_opt::quant::active_tier;
use lowbit_opt::optim::adamw::AdamW;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let min_secs = if smoke { 0.2 } else { 0.75 };

    let shapes: Vec<Vec<usize>> = vec![vec![2048, 2048]; 4]
        .into_iter()
        .chain(std::iter::once(vec![8192]))
        .collect();
    let n: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let mut grng = Pcg64::seeded(11);
    let grads: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor::randn(s, 0.01, &mut grng))
        .collect();
    let compute = 4.0 * n as f64 / 6.9e9;
    let link = LinkModel::pcie_offload(compute);
    println!(
        "synthetic model: {n} params ({} tensors); PCIe profile, modeled compute {:.2} ms/step",
        shapes.len(),
        compute * 1e3
    );

    let presets = ["adamw32", "adamw4"];
    let thread_cases = [1usize, 2, 4, 8];
    let depth_cases = [1usize, 2, 4];
    // (preset, threads, depth, wall mean ns, report, scheduler telemetry
    // — cumulative over the whole run, warmup included)
    let mut results: Vec<(&str, usize, usize, f64, OffloadReport, Option<SchedStats>)> =
        Vec::new();
    // Span-timing summary of the benched steps — `{"enabled": false}`
    // unless the bench was built with `--features trace` (satisfies the
    // bench-JSON schema either way).
    let mut trace_summary: Option<Json> = None;
    // Fault/retry/rollback counters of the last benched optimizer. The
    // bench inherits any `LOWBIT_FAULTS` gate from the environment, so
    // CI can point the schema check at a faulted record too; unset, the
    // counters are all zero.
    let mut faults_json: Option<Json> = None;

    section("offload pipeline: wall time + virtual step time (threads x depth)");
    for preset in presets {
        for &threads in &thread_cases {
            for &depth in &depth_cases {
                let mut prng = Pcg64::seeded(13);
                let mut params: Vec<Param> = shapes
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        Param::new(
                            &format!("p{i}"),
                            ParamKind::Weight,
                            Tensor::randn(s, 0.1, &mut prng),
                        )
                    })
                    .collect();
                let hp = Hyper::default();
                let ocfg = OffloadConfig::new(link, depth);
                let label = format!("{preset} t{threads} d{depth}");
                let (res, report, stats) = match preset {
                    "adamw32" => {
                        let mut opt = AdamW::new(hp).with_threads(threads).offloaded(ocfg);
                        opt.step(&mut params, &grads, 1e-3); // lazy init + tier build
                        let res = bench(&label, min_secs, || {
                            opt.step(&mut params, &grads, 1e-3);
                        });
                        if let Some(rep) = opt.step_report() {
                            if let Some(s) = &rep.spans {
                                trace_summary = Some(s.to_json());
                            }
                            if let Some(f) = &rep.faults {
                                faults_json = Some(f.to_json());
                            }
                        }
                        (res, *opt.offload_report().expect("offloaded"), opt.sched_stats())
                    }
                    _ => {
                        let mut opt = CompressedAdamW::new(hp, QuantPolicy::bit4())
                            .with_threads(threads)
                            .offloaded(ocfg);
                        opt.step(&mut params, &grads, 1e-3);
                        let res = bench(&label, min_secs, || {
                            opt.step(&mut params, &grads, 1e-3);
                        });
                        if let Some(rep) = opt.step_report() {
                            if let Some(s) = &rep.spans {
                                trace_summary = Some(s.to_json());
                            }
                            if let Some(f) = &rep.faults {
                                faults_json = Some(f.to_json());
                            }
                        }
                        (res, *opt.offload_report().expect("offloaded"), opt.sched_stats())
                    }
                };
                println!(
                    "{}  virtual {:>8.2} ms/step  overlap {:>5.1}%  \
                     ({:.1} MB down, {:.1} MB up per step)",
                    res.throughput_line(None),
                    report.step_seconds() * 1e3,
                    100.0 * report.overlap_fraction(),
                    report.bytes_down as f64 / report.steps.max(1) as f64 / 1e6,
                    report.bytes_up as f64 / report.steps.max(1) as f64 / 1e6,
                );
                results.push((preset, threads, depth, res.mean_ns, report, stats));
            }
        }
    }

    let virt = |p: &str, t: usize, d: usize| {
        results
            .iter()
            .find(|(pr, th, de, _, _, _)| *pr == p && *th == t && *de == d)
            .map(|(_, _, _, _, r, _)| r.step_seconds())
    };
    if let (Some(v32), Some(v4)) = (virt("adamw32", 4, 2), virt("adamw4", 4, 2)) {
        println!(
            "\nvirtual 4-bit-vs-32-bit speedup on PCIe (t4 d2): {:.2}x",
            v32 / v4
        );
    }

    if let Some(path) = json_path {
        let mut run = Json::obj();
        run.set("bench", Json::Str("offload_pipeline/threads-depth".to_string()));
        run.set("model_params", Json::Num(n as f64));
        run.set("smoke", Json::Bool(smoke));
        // Numbers are only comparable within a kernel tier × scheduler
        // mode; tag the run with both resolved settings.
        run.set("tier", Json::Str(active_tier().name().to_string()));
        run.set("sched", Json::Str(active_sched().name().to_string()));
        let mut jl = Json::obj();
        jl.set("bandwidth", Json::Num(link.bandwidth))
            .set("latency", Json::Num(link.latency))
            .set("compute_per_step", Json::Num(link.compute_per_step))
            .set("overlap", Json::Num(link.overlap));
        run.set("link", jl);
        let mut by_opt = Json::obj();
        for preset in presets {
            let mut by_threads = Json::obj();
            for &t in &thread_cases {
                let mut by_depth = Json::obj();
                for &d in &depth_cases {
                    if let Some((_, _, _, wall_ns, r, stats)) = results
                        .iter()
                        .find(|(pr, th, de, _, _, _)| *pr == preset && *th == t && *de == d)
                    {
                        let mut jr = Json::obj();
                        jr.set("wall_mean_us", Json::Num(wall_ns / 1e3));
                        jr.set("virtual_step_us", Json::Num(r.step_seconds() * 1e6));
                        jr.set("overlap_fraction", Json::Num(r.overlap_fraction()));
                        jr.set(
                            "down_mb_per_step",
                            Json::Num(r.bytes_down as f64 / r.steps.max(1) as f64 / 1e6),
                        );
                        if let Some(st) = stats {
                            jr.set("claims", Json::Num(st.claims as f64));
                            jr.set("steals", Json::Num(st.steals as f64));
                            jr.set("affinity_hits", Json::Num(st.affinity_hits as f64));
                        }
                        by_depth.set(&d.to_string(), jr);
                    }
                }
                by_threads.set(&t.to_string(), by_depth);
            }
            by_opt.set(preset, by_threads);
        }
        run.set("optimizers", by_opt);
        run.set(
            "trace_summary",
            trace_summary.unwrap_or_else(SpanSummary::disabled_json),
        );
        run.set(
            "faults",
            faults_json.unwrap_or_else(|| FaultCounters::default().to_json()),
        );
        append_bench_run(&path, run);
        println!("appended run to {path}");
    }
}
