//! Bench: end-to-end training step (fwd + bwd + optimizer) through the
//! builtin engine and, when artifacts exist, through the PJRT engine.
//! This is the whole-stack number the §Perf pass optimizes.

mod bench_util;

use bench_util::{bench, section};
use lowbit_opt::data::MarkovCorpus;
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::{build, Hyper, Param};
use lowbit_opt::train::TransformerEngine;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let cfg = TransformerConfig::tiny();
    let engine = TransformerEngine::new(cfg);
    let corpus = MarkovCorpus::new(cfg.vocab, 3);
    let mut rng = Pcg64::seeded(1);
    let batch = corpus.sample(8, cfg.max_seq, &mut rng);

    section("builtin engine (tiny config, batch 8)");
    for preset in ["adamw32", "adamw8", "adamw4", "factor4"] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        let res = bench(&format!("builtin fwd+bwd+{preset}"), 2.0, || {
            let (_, grads) = engine.loss_and_grads(&params, &batch);
            opt.step(&mut params, &grads, 1e-3);
        });
        println!("{}", res.throughput_line(None));
    }
    {
        let params: Vec<Param> = cfg.init_params(&mut rng);
        let res = bench("builtin fwd+bwd only", 2.0, || {
            let (l, g) = engine.loss_and_grads(&params, &batch);
            std::hint::black_box((l, g));
        });
        println!("{}", res.throughput_line(None));
    }

    let dir = lowbit_opt::util::artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        if let Ok(rt) = lowbit_opt::runtime::Runtime::cpu() {
            if let Ok(step) = lowbit_opt::runtime::PjrtTrainStep::load(&rt, &dir, "tiny") {
                section("PJRT engine (AOT artifact, batch 8)");
                let acfg = step.entry.cfg;
                let params: Vec<Param> = {
                    let mut r = Pcg64::seeded(2);
                    acfg.init_params(&mut r)
                };
                let corpus = MarkovCorpus::new(acfg.vocab, 3);
                let mut r = Pcg64::seeded(4);
                let b = corpus.sample(step.entry.batch, acfg.max_seq, &mut r);
                let res = bench("pjrt fwd+bwd (train_step_tiny)", 2.0, || {
                    let out = step.step(&params, &b).expect("pjrt step");
                    std::hint::black_box(&out);
                });
                println!("{}", res.throughput_line(None));
            }
        }
    }
}
