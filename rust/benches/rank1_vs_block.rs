//! Bench: rank-1 vs block-wise normalization — compute cost and memory
//! overhead across tensor shapes (the paper's §4.2 trade-off discussion).

mod bench_util;

use bench_util::{bench, section};
use lowbit_opt::quant::normalize::{compute_scales, NormKind};
use lowbit_opt::quant::{MapKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seeded(9);
    section("scale computation cost by shape");
    for shape in [
        vec![4096usize, 64],
        vec![512, 512],
        vec![64, 4096],
        vec![1024, 1024],
    ] {
        let x = Tensor::randn(&shape, 0.02, &mut rng);
        for kind in [NormKind::Block(128), NormKind::Block(2048), NormKind::Rank1] {
            let name = format!("{:?} {}", shape, kind.name());
            let res = bench(&name, 0.3, || {
                let s = compute_scales(&x, kind);
                std::hint::black_box(&s);
            });
            let overhead = compute_scales(&x, kind).overhead_bytes();
            println!("{}  scale-overhead {} B", res.throughput_line(None), overhead);
        }
    }

    section("full quantize cost: Rank-1/Linear vs B128/Linear (1024x1024)");
    let x = Tensor::randn(&[1024, 1024], 0.02, &mut rng).map(|v| v.abs());
    for (name, norm) in [("Rank-1", NormKind::Rank1), ("B128", NormKind::Block(128))] {
        let q = Quantizer::new(norm, MapKind::Linear, 4, false);
        let map = q.build_map();
        let mut r = Pcg64::seeded(2);
        let res = bench(name, 0.5, || {
            let qt = q.quantize_with(&x, &map, &mut r);
            std::hint::black_box(&qt);
        });
        println!("{}", res.throughput_line(Some(4 << 20)));
    }
}
