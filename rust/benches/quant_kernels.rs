//! Bench: nibble-granular quant kernel throughput (PR 5) — fused
//! normalize→encode→pack, pair-LUT decode, and the full roundtrip, in
//! Melem/s per paper preset. This is the layer every optimizer step's
//! inner loops run on (the `quant/kernels` tier), so its trajectory is
//! tracked in BENCH_quant.json the way the step engine's is in
//! BENCH_engine.json. Each run records the resolved kernel tier
//! (scalar/avx2) — numbers are only comparable within a tier; force one
//! with `LOWBIT_KERNEL_TIER=scalar|avx2`.
//!
//! Flags:
//!   --smoke        short measurement windows (CI)
//!   --json PATH    append a run object to PATH (BENCH_quant.json)

mod bench_util;

use bench_util::{append_bench_run, bench, section};
use lowbit_opt::quant::{active_tier, MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let min_secs = if smoke { 0.1 } else { 0.5 };

    let n: usize = 1 << 20; // 1M elements
    let mut rng = Pcg64::seeded(7);
    let x2d = Tensor::randn(&[1024, 1024], 0.02, &mut rng);
    let x1d = Tensor::randn(&[n], 0.02, &mut rng);
    let melem = |mean_ns: f64| n as f64 * 1e3 / mean_ns;

    // The paper presets the optimizer hot paths actually run, plus the
    // per-tensor arm (rank-1's 1-D fallback in phase A/C).
    let cases: Vec<(&str, Quantizer, bool)> = vec![
        ("B128/DE 4-bit (m)", Quantizer::first_moment_4bit(), false),
        ("Rank-1/Linear 4-bit (v)", Quantizer::second_moment_4bit(), false),
        (
            "B128/Linear 4-bit (v 1-D)",
            Quantizer::new(NormKind::Block(128), MapKind::Linear, 4, false),
            true,
        ),
        ("B2048/DE 8-bit (Dettmers m)", Quantizer::moment_8bit(true), false),
        (
            "per-tensor/Linear 4-bit",
            Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
            false,
        ),
    ];

    let mut results: Vec<(String, f64, f64, f64)> = Vec::new();
    section(&format!(
        "fused encode / pair-LUT decode / roundtrip (1M elements, {} tier)",
        active_tier().name()
    ));
    for (name, q, use_1d) in &cases {
        let x = if *use_1d { &x1d } else { &x2d };
        let map = q.build_map();
        let mut r = Pcg64::seeded(1);
        let enc = bench(&format!("{name} encode"), min_secs, || {
            let qt = q.quantize_with(x, &map, &mut r);
            std::hint::black_box(&qt);
        });
        println!("{}  {:>8.1} Melem/s", enc.throughput_line(None), melem(enc.mean_ns));
        let qt = q.quantize_with(x, &map, &mut r);
        let dec = bench(&format!("{name} decode"), min_secs, || {
            let t = qt.dequantize_with(&map);
            std::hint::black_box(&t);
        });
        println!("{}  {:>8.1} Melem/s", dec.throughput_line(None), melem(dec.mean_ns));
        let rt = bench(&format!("{name} roundtrip"), min_secs, || {
            let qt = q.quantize_with(x, &map, &mut r);
            let t = qt.dequantize_with(&map);
            std::hint::black_box(&t);
        });
        println!("{}  {:>8.1} Melem/s", rt.throughput_line(None), melem(rt.mean_ns));
        results.push((
            name.to_string(),
            melem(enc.mean_ns),
            melem(dec.mean_ns),
            melem(rt.mean_ns),
        ));
    }

    if let Some(path) = json_path {
        let mut run = Json::obj();
        run.set("bench", Json::Str("quant_kernels".to_string()));
        run.set("tier", Json::Str(active_tier().name().to_string()));
        run.set("elems", Json::Num(n as f64));
        run.set("smoke", Json::Bool(smoke));
        let mut by_case = Json::obj();
        for (name, enc, dec, rt) in &results {
            let mut jr = Json::obj();
            jr.set("encode_melem_s", Json::Num(*enc));
            jr.set("decode_melem_s", Json::Num(*dec));
            jr.set("roundtrip_melem_s", Json::Num(*rt));
            by_case.set(name, jr);
        }
        run.set("cases", by_case);
        append_bench_run(&path, run);
        println!("appended run to {path}");
    }
}
