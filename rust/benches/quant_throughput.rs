//! Bench: quantize + dequantize throughput by bitwidth, mapping, and
//! normalization (the L3 hot path; supports the paper's Tab. 4 time
//! discussion). Reported in GB/s of f32 input processed.

mod bench_util;

use bench_util::{bench, section};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let min_secs = if smoke { 0.1 } else { 0.5 };
    let mut rng = Pcg64::seeded(7);
    let n = 1 << 20; // 1M elements = 4 MB
    let x2d = Tensor::randn(&[1024, 1024], 0.02, &mut rng);
    let bytes = (n * 4) as u64;

    section("quantize (1M f32)");
    let cases: Vec<(&str, Quantizer)> = vec![
        ("B128/DE 4-bit signed (m, ours)", Quantizer::first_moment_4bit()),
        ("Rank-1/Linear 4-bit (v, ours)", Quantizer::second_moment_4bit()),
        (
            "B128/Linear 4-bit",
            Quantizer::new(NormKind::Block(128), MapKind::Linear, 4, false),
        ),
        ("B2048/DE 8-bit signed (Dettmers)", Quantizer::moment_8bit(true)),
        (
            "B2048/DE 4-bit signed",
            Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true),
        ),
        (
            "per-tensor/Linear 4-bit",
            Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
        ),
        (
            "B128/DE+SR 4-bit (stochastic)",
            Quantizer::first_moment_4bit().with_stochastic(true),
        ),
    ];
    for (name, q) in &cases {
        let map = q.build_map();
        let mut r = Pcg64::seeded(1);
        let res = bench(name, min_secs, || {
            let qt = q.quantize_with(&x2d, &map, &mut r);
            std::hint::black_box(&qt);
        });
        println!("{}", res.throughput_line(Some(bytes)));
    }

    section("dequantize (1M codes)");
    for (name, q) in &cases {
        let map = q.build_map();
        let mut r = Pcg64::seeded(1);
        let qt = q.quantize_with(&x2d, &map, &mut r);
        let res = bench(name, min_secs, || {
            let t = qt.dequantize_with(&map);
            std::hint::black_box(&t);
        });
        println!("{}", res.throughput_line(Some(bytes)));
    }

    section("roundtrip (quantize + dequantize)");
    let q = Quantizer::first_moment_4bit();
    let map = q.build_map();
    let mut r = Pcg64::seeded(1);
    let res = bench("B128/DE 4-bit roundtrip", min_secs, || {
        let qt = q.quantize_with(&x2d, &map, &mut r);
        let t = qt.dequantize_with(&map);
        std::hint::black_box(&t);
    });
    println!("{}", res.throughput_line(Some(bytes)));
}
