//! Bench: full optimizer step time per preset on a realistic parameter
//! set (the small transformer config). Regenerates the measured half of
//! the paper's Tab. 4 and quantifies the unfused 4-bit overhead.

mod bench_util;

use bench_util::{bench, section};
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::{build, Hyper, Param};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let cfg = TransformerConfig::small();
    let mut rng = Pcg64::seeded(5);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let n_params: usize = cfg.n_params();
    println!("model: {} params ({} tensors)", n_params, grads.len());

    section("optimizer step (full parameter set)");
    for preset in ["adamw32", "sgdm", "adafactor", "adafactor-b0", "sm3", "adamw8", "adamw4", "adamw4-sr", "factor4"] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        opt.step(&mut params, &grads, 1e-3); // lazy init outside the timer
        let res = bench(preset, 1.0, || {
            opt.step(&mut params, &grads, 1e-3);
        });
        let ns_per_param = res.mean_ns / n_params as f64;
        println!(
            "{}  {:>6.2} ns/param  state {} B",
            res.throughput_line(None),
            ns_per_param,
            opt.state_bytes()
        );
    }

    // The fused PJRT path, when artifacts are present.
    let dir = lowbit_opt::util::artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        if let Ok(rt) = lowbit_opt::runtime::Runtime::cpu() {
            if let Ok(mut fused) =
                lowbit_opt::runtime::fused::FusedAdamW4::load(&rt, &dir, Hyper::default())
            {
                section("fused AOT path (PJRT; paper's '(fused)' rows)");
                let mut params: Vec<Param> = cfg.init_params(&mut rng);
                fused.step(&mut params, &grads, 1e-3);
                use lowbit_opt::optim::Optimizer;
                let res = bench("adamw4-fused (pjrt)", 2.0, || {
                    fused.step(&mut params, &grads, 1e-3);
                });
                println!(
                    "{}  {:>6.2} ns/param",
                    res.throughput_line(None),
                    res.mean_ns / n_params as f64
                );
            }
        }
    }
}
