//! Bench: full optimizer step time per preset on a realistic parameter
//! set (the small transformer config), plus the shard-parallel engine's
//! thread scaling on a ≥16M-parameter synthetic model — the CPU analogue
//! of the paper's Tab. 4 "(fused)" speed story.
//!
//! Flags:
//!   --smoke        short measurement windows (CI)
//!   --json PATH    write the engine-scaling results (BENCH_engine.json)

mod bench_util;

use bench_util::{bench, section, BenchResult};
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{build, Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let min_secs = if smoke { 0.25 } else { 1.0 };

    let cfg = TransformerConfig::small();
    let mut rng = Pcg64::seeded(5);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let n_params: usize = cfg.n_params();
    println!("model: {} params ({} tensors)", n_params, grads.len());

    section("optimizer step (full parameter set)");
    for preset in [
        "adamw32",
        "sgdm",
        "adafactor",
        "adafactor-b0",
        "sm3",
        "adamw8",
        "adamw4",
        "adamw4-sr",
        "factor4",
    ] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        opt.step(&mut params, &grads, 1e-3); // lazy init outside the timer
        let res = bench(preset, min_secs, || {
            opt.step(&mut params, &grads, 1e-3);
        });
        let ns_per_param = res.mean_ns / n_params as f64;
        println!(
            "{}  {:>6.2} ns/param  state {} B",
            res.throughput_line(None),
            ns_per_param,
            opt.state_bytes()
        );
    }

    // --------------------------------------------------------------
    // Shard-parallel engine scaling: 4-bit AdamW on a ≥16M-parameter
    // synthetic set. threads=1 is the sequential schedule (the seed's
    // per-tensor loop shape); higher counts run the same plan parallel.
    // --------------------------------------------------------------
    section("shard-parallel engine scaling (synthetic >=16M params, adamw4)");
    let shapes: Vec<Vec<usize>> = vec![vec![2048, 2048]; 4]
        .into_iter()
        .chain(std::iter::once(vec![8192]))
        .collect();
    let big_n: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let mut brng = Pcg64::seeded(11);
    let big_grads: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor::randn(s, 0.01, &mut brng))
        .collect();
    println!("synthetic model: {big_n} params ({} tensors)", shapes.len());

    let thread_cases = [1usize, 2, 4, 8];
    let mut results: Vec<(usize, BenchResult)> = Vec::new();
    for &threads in &thread_cases {
        let mut opt =
            CompressedAdamW::new(Hyper::default(), QuantPolicy::bit4()).with_threads(threads);
        let mut prng = Pcg64::seeded(13);
        let mut params: Vec<Param> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Param::new(
                    &format!("p{i}"),
                    ParamKind::Weight,
                    Tensor::randn(s, 0.1, &mut prng),
                )
            })
            .collect();
        opt.step(&mut params, &big_grads, 1e-3); // lazy init outside the timer
        let res = bench(
            &format!("adamw4 engine, {threads} thread(s)"),
            min_secs.max(0.3),
            || {
                opt.step(&mut params, &big_grads, 1e-3);
            },
        );
        println!(
            "{}  {:>6.2} ns/param",
            res.throughput_line(None),
            res.mean_ns / big_n as f64
        );
        results.push((threads, res));
    }
    let mean_of = |t: usize| {
        results
            .iter()
            .find(|(th, _)| *th == t)
            .map(|(_, r)| r.mean_ns)
    };
    if let (Some(t1), Some(t4)) = (mean_of(1), mean_of(4)) {
        println!("speedup at 4 threads vs sequential: {:.2}x", t1 / t4);
    }

    if let Some(path) = json_path {
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("optim_step/engine-scaling".to_string()));
        doc.set("optimizer", Json::Str("adamw4".to_string()));
        doc.set("model_params", Json::Num(big_n as f64));
        doc.set("smoke", Json::Bool(smoke));
        let mut by_threads = Json::obj();
        for (t, r) in &results {
            let mut jr = Json::obj();
            jr.set("mean_us", Json::Num(r.mean_ns / 1e3));
            jr.set("p50_us", Json::Num(r.p50_ns / 1e3));
            jr.set("p95_us", Json::Num(r.p95_ns / 1e3));
            jr.set("iters", Json::Num(r.iters as f64));
            by_threads.set(&t.to_string(), jr);
        }
        doc.set("threads", by_threads);
        if let (Some(t1), Some(t2)) = (mean_of(1), mean_of(2)) {
            doc.set("speedup_2t", Json::Num(t1 / t2));
        }
        if let (Some(t1), Some(t4)) = (mean_of(1), mean_of(4)) {
            doc.set("speedup_4t", Json::Num(t1 / t4));
        }
        if let (Some(t1), Some(t8)) = (mean_of(1), mean_of(8)) {
            doc.set("speedup_8t", Json::Num(t1 / t8));
        }
        lowbit_opt::util::write_file(&path, &doc.pretty()).expect("write bench json");
        println!("wrote {path}");
    }

    // The fused PJRT path, when artifacts are present.
    let dir = lowbit_opt::util::artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        if let Ok(rt) = lowbit_opt::runtime::Runtime::cpu() {
            if let Ok(mut fused) =
                lowbit_opt::runtime::fused::FusedAdamW4::load(&rt, &dir, Hyper::default())
            {
                section("fused AOT path (PJRT; paper's '(fused)' rows)");
                let mut params: Vec<Param> = cfg.init_params(&mut rng);
                fused.step(&mut params, &grads, 1e-3);
                let res = bench("adamw4-fused (pjrt)", 2.0, || {
                    fused.step(&mut params, &grads, 1e-3);
                });
                println!(
                    "{}  {:>6.2} ns/param",
                    res.throughput_line(None),
                    res.mean_ns / n_params as f64
                );
            }
        }
    }
}
