//! Bench: full optimizer step time per preset on a realistic parameter
//! set (the small transformer config), plus engine thread scaling of the
//! dense baselines *and* the compressed optimizer on a ≥16M-parameter
//! synthetic model — the CPU analogue of the paper's Tab. 4 "(fused)"
//! speed story, apples-to-apples because every optimizer shards through
//! the same step engine.
//!
//! Flags:
//!   --smoke        short measurement windows (CI)
//!   --json PATH    append the engine-scaling run to PATH
//!                  (BENCH_engine.json keeps one entry per CI run, so
//!                  the perf trajectory stays visible across PRs)

mod bench_util;

use bench_util::{append_bench_run, bench, section, BenchResult};
use lowbit_opt::engine::{active_sched, SchedMode, SchedStats};
use lowbit_opt::obs::report::{FaultCounters, SpanSummary};
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{build, build_threaded, Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::active_tier;
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let min_secs = if smoke { 0.25 } else { 1.0 };

    let cfg = TransformerConfig::small();
    let mut rng = Pcg64::seeded(5);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let n_params: usize = cfg.n_params();
    println!("model: {} params ({} tensors)", n_params, grads.len());

    section("optimizer step (full parameter set)");
    for preset in [
        "adamw32",
        "sgdm",
        "adafactor",
        "adafactor-b0",
        "sm3",
        "adamw8",
        "adamw4",
        "adamw4-sr",
        "factor4",
    ] {
        let mut params: Vec<Param> = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        opt.step(&mut params, &grads, 1e-3); // lazy init outside the timer
        let res = bench(preset, min_secs, || {
            opt.step(&mut params, &grads, 1e-3);
        });
        let ns_per_param = res.mean_ns / n_params as f64;
        println!(
            "{}  {:>6.2} ns/param  state {} B",
            res.throughput_line(None),
            ns_per_param,
            opt.state_bytes()
        );
    }

    // --------------------------------------------------------------
    // Shard-parallel engine scaling, dense vs compressed, on a ≥16M-
    // parameter synthetic set. threads=1 is the sequential schedule of
    // the same plan; higher counts run it parallel on the persistent
    // worker pool. Recording dense baselines alongside adamw4 makes the
    // Tab. 4 comparison apples-to-apples at every thread count.
    // --------------------------------------------------------------
    section("shard-parallel engine scaling (synthetic >=16M params, dense vs compressed)");
    let shapes: Vec<Vec<usize>> = vec![vec![2048, 2048]; 4]
        .into_iter()
        .chain(std::iter::once(vec![8192]))
        .collect();
    let big_n: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let mut brng = Pcg64::seeded(11);
    let big_grads: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor::randn(s, 0.01, &mut brng))
        .collect();
    println!("synthetic model: {big_n} params ({} tensors)", shapes.len());

    let scaling_presets = ["adamw32", "sgdm", "sm3", "adamw4"];
    let thread_cases = [1usize, 2, 4, 8];
    // (preset, threads, cold-step ns, warm steady-state result,
    // scheduler telemetry). The cold step re-pays the full
    // plan/meta/arena construction (the caches are invalidated right
    // before it); the warm numbers are the steady state that reuses the
    // step context. Keeping both in the bench JSON makes the cache win —
    // and any regression of either path — visible across PRs. The
    // telemetry is cumulative over the whole run (warmup included).
    let mut results: Vec<(&str, usize, f64, BenchResult, Option<SchedStats>)> = Vec::new();
    for preset in scaling_presets {
        for &threads in &thread_cases {
            let mut opt = build_threaded(preset, Hyper::default(), threads).unwrap();
            let mut prng = Pcg64::seeded(13);
            let mut params: Vec<Param> = shapes
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Param::new(
                        &format!("p{i}"),
                        ParamKind::Weight,
                        Tensor::randn(s, 0.1, &mut prng),
                    )
                })
                .collect();
            // Lazy state init + first context build, outside every timer.
            opt.step(&mut params, &big_grads, 1e-3);
            // Cold step: context invalidated, so this one step re-runs
            // meta/plan construction and arena allocation (state init
            // stays warm — that is one-time, not per-reconfiguration).
            opt.invalidate_step_cache();
            let t0 = std::time::Instant::now();
            opt.step(&mut params, &big_grads, 1e-3);
            let cold_ns = t0.elapsed().as_nanos() as f64;
            let res = bench(
                &format!("{preset} engine, {threads} thread(s)"),
                min_secs.max(0.25),
                || {
                    opt.step(&mut params, &big_grads, 1e-3);
                },
            );
            println!(
                "{}  {:>6.2} ns/param  (cold first step {:>8.1} us)",
                res.throughput_line(None),
                res.mean_ns / big_n as f64,
                cold_ns / 1e3
            );
            results.push((preset, threads, cold_ns, res, opt.sched_stats()));
        }
    }
    let mean_of = |p: &str, t: usize| {
        results
            .iter()
            .find(|(pr, th, _, _, _)| *pr == p && *th == t)
            .map(|(_, _, _, r, _)| r.mean_ns)
    };
    for preset in scaling_presets {
        if let (Some(t1), Some(t4)) = (mean_of(preset, 1), mean_of(preset, 4)) {
            println!("{preset}: speedup at 4 threads vs sequential: {:.2}x", t1 / t4);
        }
    }
    if let (Some(dense), Some(comp)) = (mean_of("adamw32", 8), mean_of("adamw4", 8)) {
        println!(
            "at 8 threads: adamw4 step is {:.2}x the adamw32 step time \
             (same engine, same plan machinery)",
            comp / dense
        );
    }

    // --------------------------------------------------------------
    // Scheduler comparison: the same adamw4 workload at 8 threads under
    // the shared-queue reference vs the sticky affinity scheduler (both
    // pinned per-engine, so one process measures both). Warm sticky must
    // be no slower than queue — the BENCH_engine.json record below is
    // the acceptance gate — and the telemetry shows why: warm sticky
    // steps re-claim their learned shards instead of racing one atomic.
    // --------------------------------------------------------------
    section("scheduler modes: queue vs sticky (adamw4, 8 threads)");
    let mut sched_results: Vec<(&'static str, BenchResult, SchedStats)> = Vec::new();
    // Span-timing summary of the benched steps — `{"enabled": false}`
    // unless the bench was built with `--features trace` (satisfies the
    // bench-JSON schema either way).
    let mut trace_summary: Option<Json> = None;
    // Fault/retry/rollback counters of the benched optimizer — all
    // zeros here (no fault plan is armed in the bench), but the key is
    // schema-required so fault regressions stay visible in CI.
    let mut faults_json: Option<Json> = None;
    for mode in [SchedMode::Queue, SchedMode::Sticky] {
        let mut opt = CompressedAdamW::new(Hyper::default(), QuantPolicy::bit4())
            .with_threads(8)
            .with_sched(mode);
        let mut prng = Pcg64::seeded(13);
        let mut params: Vec<Param> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Param::new(
                    &format!("p{i}"),
                    ParamKind::Weight,
                    Tensor::randn(s, 0.1, &mut prng),
                )
            })
            .collect();
        opt.step(&mut params, &big_grads, 1e-3); // lazy init + context build
        let res = bench(
            &format!("adamw4 engine, 8 threads, {} sched", mode.name()),
            min_secs.max(0.25),
            || {
                opt.step(&mut params, &big_grads, 1e-3);
            },
        );
        let stats = opt.sched_stats().expect("engine-backed optimizer");
        if let Some(rep) = opt.step_report() {
            if let Some(s) = &rep.spans {
                trace_summary = Some(s.to_json());
            }
            if let Some(f) = &rep.faults {
                faults_json = Some(f.to_json());
            }
        }
        println!(
            "{}  claims {}  steals {}  affinity hits {}",
            res.throughput_line(None),
            stats.claims,
            stats.steals,
            stats.affinity_hits
        );
        sched_results.push((mode.name(), res, stats));
    }
    if let [(_, q, _), (_, s, _)] = &sched_results[..] {
        println!(
            "sticky warm mean is {:.3}x the queue warm mean (<= 1 is the win)",
            s.mean_ns / q.mean_ns
        );
    }

    if let Some(path) = json_path {
        let mut run = Json::obj();
        run.set("bench", Json::Str("optim_step/engine-scaling".to_string()));
        run.set("model_params", Json::Num(big_n as f64));
        run.set("smoke", Json::Bool(smoke));
        // Numbers are only comparable within a kernel tier × scheduler
        // mode; tag the run with both resolved settings.
        run.set("tier", Json::Str(active_tier().name().to_string()));
        run.set("sched", Json::Str(active_sched().name().to_string()));
        let mut by_opt = Json::obj();
        for preset in scaling_presets {
            let mut entry = Json::obj();
            let mut by_threads = Json::obj();
            for &t in &thread_cases {
                if let Some((_, _, cold_ns, r, stats)) =
                    results.iter().find(|(pr, th, _, _, _)| *pr == preset && *th == t)
                {
                    let mut jr = Json::obj();
                    // mean/p50/p95 are the warm steady state (cache hit);
                    // cold_step_us is the one invalidated step that
                    // rebuilds the plan/meta/arenas.
                    jr.set("mean_us", Json::Num(r.mean_ns / 1e3));
                    jr.set("p50_us", Json::Num(r.p50_ns / 1e3));
                    jr.set("p95_us", Json::Num(r.p95_ns / 1e3));
                    jr.set("cold_step_us", Json::Num(cold_ns / 1e3));
                    jr.set("iters", Json::Num(r.iters as f64));
                    if let Some(st) = stats {
                        jr.set("claims", Json::Num(st.claims as f64));
                        jr.set("steals", Json::Num(st.steals as f64));
                        jr.set("affinity_hits", Json::Num(st.affinity_hits as f64));
                    }
                    by_threads.set(&t.to_string(), jr);
                }
            }
            entry.set("threads", by_threads);
            for &t in &thread_cases[1..] {
                if let (Some(t1), Some(tt)) = (mean_of(preset, 1), mean_of(preset, t)) {
                    entry.set(&format!("speedup_{t}t"), Json::Num(t1 / tt));
                }
            }
            by_opt.set(preset, entry);
        }
        run.set("optimizers", by_opt);
        let mut by_sched = Json::obj();
        for (name, r, stats) in &sched_results {
            let mut jr = Json::obj();
            jr.set("mean_us", Json::Num(r.mean_ns / 1e3));
            jr.set("p50_us", Json::Num(r.p50_ns / 1e3));
            jr.set("p95_us", Json::Num(r.p95_ns / 1e3));
            jr.set("iters", Json::Num(r.iters as f64));
            jr.set("claims", Json::Num(stats.claims as f64));
            jr.set("steals", Json::Num(stats.steals as f64));
            jr.set("affinity_hits", Json::Num(stats.affinity_hits as f64));
            by_sched.set(name, jr);
        }
        run.set("sched_compare_8t", by_sched);
        run.set(
            "trace_summary",
            trace_summary.unwrap_or_else(SpanSummary::disabled_json),
        );
        run.set(
            "faults",
            faults_json.unwrap_or_else(|| FaultCounters::default().to_json()),
        );
        append_bench_run(&path, run);
        println!("appended run to {path}");
    }

    // The fused PJRT path, when artifacts are present.
    let dir = lowbit_opt::util::artifacts_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        if let Ok(rt) = lowbit_opt::runtime::Runtime::cpu() {
            if let Ok(mut fused) =
                lowbit_opt::runtime::fused::FusedAdamW4::load(&rt, &dir, Hyper::default())
            {
                section("fused AOT path (PJRT; paper's '(fused)' rows)");
                let mut params: Vec<Param> = cfg.init_params(&mut rng);
                fused.step(&mut params, &grads, 1e-3);
                let res = bench("adamw4-fused (pjrt)", 2.0, || {
                    fused.step(&mut params, &grads, 1e-3);
                });
                println!(
                    "{}  {:>6.2} ns/param",
                    res.throughput_line(None),
                    res.mean_ns / n_params as f64
                );
            }
        }
    }
}
