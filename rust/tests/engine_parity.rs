//! Determinism parity suite for the shard-parallel step engine, covering
//! **every engine-backed optimizer** (dense and compressed) on the
//! persistent worker pool:
//!
//! * `CompressedAdamW` stepped at thread counts 1 (the sequential
//!   schedule), 2 and 7 must produce **bit-identical** weights and
//!   optimizer states — for every quantization policy, with stochastic
//!   rounding ON and OFF, factored and quantized second moments, and
//!   both 1-D and 2-D parameters.
//! * The dense baselines (fp32 AdamW, SGDM, SM3) must be bit-identical
//!   to their **off-engine sequential reference loops** at every thread
//!   count (elementwise updates and max-reductions are exact under any
//!   sharding).
//! * Adafactor must be bit-identical across thread counts (its float-sum
//!   reductions associate per shard, fixed by the plan) and bit-identical
//!   to the sequential reference at every shard size: both sides
//!   accumulate the column/RMS sums with compensated
//!   (Kahan-Babuska-Neumaier) f64 summation, whose per-shard partials
//!   merge back to the element-order sum (exactly for single-shard
//!   tensors, to far below f32 granularity for multi-shard ones).
//!
//! Shard size is forced down to 512 elements so even these small test
//! tensors split into many shards (the 2-D weight into ~5, the 1-D
//! vector into ~12), making the parity check exercise real multi-shard
//! plans rather than trivially passing on single-shard tensors.

use lowbit_opt::optim::adafactor::Adafactor;
use lowbit_opt::optim::adamw::AdamW;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::sgdm::Sgdm;
use lowbit_opt::optim::sm3::Sm3;
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

const SHARD_ELEMS: usize = 512;
const STEPS: usize = 4;
const THREADS: [usize; 3] = [1, 2, 7];

/// Everything observable about a run: final weights, decompressed
/// moments, and the persistent state footprint.
#[derive(PartialEq, Debug)]
struct RunOut {
    weights: Vec<Vec<f32>>,
    moments: Vec<(Vec<f32>, Vec<f32>)>,
    state_bytes: usize,
}

fn mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(7);
    vec![
        // 2-D, multi-shard under rank-1 row alignment.
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[40, 96], 0.5, &mut rng)),
        // 1-D, multi-shard under B128 alignment.
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[6000], 0.5, &mut rng)),
        // 2-D, two shards.
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        // Tiny tensor, coalesced with whatever shard has room.
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

/// Larger workload (> `MIN_PARALLEL_ELEMS` = 32768 total elements) so
/// auto thread mode genuinely goes parallel instead of short-circuiting
/// to the sequential schedule.
fn big_mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(17);
    vec![
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[64, 384], 0.5, &mut rng)),
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[12000], 0.5, &mut rng)),
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

fn run_params(policy: QuantPolicy, threads: usize, mk: fn() -> Vec<Param>) -> RunOut {
    let hp = Hyper::default();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(threads)
        .with_shard_elems(SHARD_ELEMS);
    let mut params = mk();
    let init: Vec<Vec<f32>> = params.iter().map(|p| p.tensor.data.clone()).collect();
    for s in 0..STEPS {
        // Same gradient stream for every run: re-seeded per step.
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        opt.step(&mut params, &grads, 1e-2);
    }
    // The optimizer must have actually moved the weights.
    for (p, w0) in params.iter().zip(init.iter()) {
        assert_ne!(&p.tensor.data, w0, "{} never updated", p.name);
    }
    RunOut {
        weights: params.iter().map(|p| p.tensor.data.clone()).collect(),
        moments: (0..params.len())
            .map(|i| {
                let (m, v) = opt.moments(i).expect("moments");
                (m.data, v.data)
            })
            .collect(),
        state_bytes: opt.state_bytes(),
    }
}

fn run(policy: QuantPolicy, threads: usize) -> RunOut {
    run_params(policy, threads, mixed_params)
}

fn assert_parity(mk_policy: impl Fn() -> QuantPolicy, label: &str) {
    let baseline = run(mk_policy(), THREADS[0]);
    for &t in &THREADS[1..] {
        let out = run(mk_policy(), t);
        assert_eq!(
            baseline, out,
            "{label}: threads={t} diverged from the sequential schedule"
        );
    }
}

fn quantize_everything(mut policy: QuantPolicy) -> QuantPolicy {
    policy.min_quant_size = 0;
    policy
}

#[test]
fn parity_bit4_deterministic_rounding() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4()),
        "4-bit (m B128/DE, v Rank-1/Linear), SR off",
    );
}

#[test]
fn parity_bit4_stochastic_rounding() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().stochastic()),
        "4-bit, SR on",
    );
}

#[test]
fn parity_bit4_factored() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().factored()),
        "4-bit Factor, SR off",
    );
}

#[test]
fn parity_bit4_factored_stochastic() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().factored().stochastic()),
        "4-bit Factor, SR on",
    );
}

#[test]
fn parity_bit8_blockwise() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit8()),
        "8-bit (B2048/DE both moments)",
    );
}

#[test]
fn parity_per_tensor_v() {
    // Per-tensor normalization exercises the global-scale route with a
    // single reduced statistic (and on 1-D tensors too).
    assert_parity(
        || {
            quantize_everything(QuantPolicy::bit4().with_v(Some(Quantizer::new(
                NormKind::PerTensor,
                MapKind::Linear,
                4,
                false,
            ))))
        },
        "4-bit m + per-tensor/Linear v",
    );
}

#[test]
fn parity_fp32_states_match_dense_adamw() {
    // With quantization fully disabled the engine must still be
    // bit-identical to the dense AdamW baseline at every thread count —
    // the update kernel is the same arithmetic, shard split or not.
    let policy = QuantPolicy {
        m_quant: None,
        v_quant: None,
        v_quant_1d: None,
        factor_v: false,
        min_quant_size: 0,
        skip_embedding: false,
    };
    let hp = Hyper::default();
    let mut dense = lowbit_opt::optim::adamw::AdamW::new(hp);
    let mut dense_params = mixed_params();
    for s in 0..STEPS {
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = dense_params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        dense.step(&mut dense_params, &grads, 1e-2);
    }
    for &t in &THREADS {
        let mut opt = CompressedAdamW::new(hp, policy)
            .with_threads(t)
            .with_shard_elems(SHARD_ELEMS);
        let mut params = mixed_params();
        for s in 0..STEPS {
            let mut grng = Pcg64::seeded(1000 + s as u64);
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
                .collect();
            opt.step(&mut params, &grads, 1e-2);
        }
        for (a, b) in params.iter().zip(dense_params.iter()) {
            assert_eq!(
                a.tensor.data, b.tensor.data,
                "fp32 engine at {t} threads != dense AdamW for {}",
                a.name
            );
        }
    }
}

// ---------------------------------------------------------------------
// Dense baselines on the engine.
// ---------------------------------------------------------------------

/// Everything observable about a dense-optimizer run: final weights plus
/// one flattened state vector per parameter.
#[derive(PartialEq, Debug)]
struct DenseOut {
    weights: Vec<Vec<f32>>,
    states: Vec<Vec<f32>>,
}

fn run_dense<O: Optimizer>(
    mut opt: O,
    mk: fn() -> Vec<Param>,
    extract: impl Fn(&O, usize) -> Vec<f32>,
) -> DenseOut {
    let mut params = mk();
    let init: Vec<Vec<f32>> = params.iter().map(|p| p.tensor.data.clone()).collect();
    for s in 0..STEPS {
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        opt.step(&mut params, &grads, 1e-2);
    }
    for (p, w0) in params.iter().zip(init.iter()) {
        assert_ne!(&p.tensor.data, w0, "{} never updated", p.name);
    }
    DenseOut {
        weights: params.iter().map(|p| p.tensor.data.clone()).collect(),
        states: (0..params.len()).map(|i| extract(&opt, i)).collect(),
    }
}

fn adamw_state(o: &AdamW, i: usize) -> Vec<f32> {
    let (m, v) = o.moments(i).expect("moments");
    m.data.iter().chain(v.data.iter()).copied().collect()
}

fn sgdm_state(o: &Sgdm, i: usize) -> Vec<f32> {
    o.momentum(i).expect("momentum").data
}

fn sm3_state(o: &Sm3, i: usize) -> Vec<f32> {
    let (a, b) = o.accumulators(i).expect("accumulators");
    let mut s = o.momentum(i).expect("momentum").data.clone();
    s.extend(a);
    s.extend(b);
    s
}

fn adafactor_state(o: &Adafactor, i: usize) -> Vec<f32> {
    let (r, c) = o.second(i).expect("second moment");
    let mut s = r;
    s.extend(c);
    if let Some(m) = o.momentum(i) {
        s.extend(m.data.iter());
    }
    s
}

#[test]
fn parity_dense_adamw32_on_vs_off_engine() {
    let hp = Hyper::default();
    let reference = run_dense(AdamW::sequential(hp), mixed_params, adamw_state);
    for &t in &THREADS {
        let opt = AdamW::new(hp).with_threads(t).with_shard_elems(SHARD_ELEMS);
        let out = run_dense(opt, mixed_params, adamw_state);
        assert_eq!(
            reference, out,
            "adamw32: engine at {t} threads != sequential reference"
        );
    }
}

#[test]
fn parity_dense_sgdm_on_vs_off_engine() {
    let hp = Hyper::default();
    let reference = run_dense(Sgdm::sequential(hp, None), mixed_params, sgdm_state);
    for &t in &THREADS {
        let opt = Sgdm::new(hp, None)
            .with_threads(t)
            .with_shard_elems(SHARD_ELEMS);
        let out = run_dense(opt, mixed_params, sgdm_state);
        assert_eq!(
            reference, out,
            "sgdm: engine at {t} threads != sequential reference"
        );
    }
}

#[test]
fn parity_dense_sm3_on_vs_off_engine() {
    let hp = Hyper::default();
    let reference = run_dense(Sm3::sequential(hp), mixed_params, sm3_state);
    for &t in &THREADS {
        let opt = Sm3::new(hp).with_threads(t).with_shard_elems(SHARD_ELEMS);
        let out = run_dense(opt, mixed_params, sm3_state);
        assert_eq!(
            reference, out,
            "sm3: engine at {t} threads != sequential reference"
        );
    }
}

#[test]
fn parity_adafactor_bit_identical_across_threads() {
    for momentum in [true, false] {
        let hp = Hyper::default();
        let mk = |t: usize| {
            Adafactor::new(hp, momentum)
                .with_threads(t)
                .with_shard_elems(SHARD_ELEMS)
        };
        let baseline = run_dense(mk(THREADS[0]), mixed_params, adafactor_state);
        for &t in &THREADS[1..] {
            let out = run_dense(mk(t), mixed_params, adafactor_state);
            assert_eq!(
                baseline, out,
                "adafactor(momentum={momentum}): threads={t} diverged from the \
                 1-thread schedule"
            );
        }
    }
}

#[test]
fn adafactor_single_shard_matches_sequential_reference_bitwise() {
    // With the default shard size every mixed_params tensor is a single
    // piece, so the per-shard sums have exactly one partial each and the
    // engine must reproduce the sequential reference bit-for-bit.
    let hp = Hyper::default();
    let reference = run_dense(Adafactor::sequential(hp, true), mixed_params, adafactor_state);
    let engine = run_dense(Adafactor::new(hp, true).with_threads(4), mixed_params, adafactor_state);
    assert_eq!(reference, engine, "adafactor single-shard engine != sequential");
}

#[test]
fn adafactor_multi_shard_matches_sequential_reference_bitwise() {
    // Multi-shard plans regroup the column and RMS sums per shard, but
    // both the engine and the sequential reference accumulate them with
    // compensated (Kahan-Babuska-Neumaier) f64 summation: the shard-
    // order merge of compensated partials reproduces the element-order
    // sum to second order in the f64 epsilon, far below the f32 state
    // granularity — so the weights and states must match bit-for-bit
    // (row sums are shard-local and match trivially).
    for momentum in [true, false] {
        let hp = Hyper::default();
        let reference = run_dense(
            Adafactor::sequential(hp, momentum),
            mixed_params,
            adafactor_state,
        );
        let engine = run_dense(
            Adafactor::new(hp, momentum)
                .with_threads(4)
                .with_shard_elems(SHARD_ELEMS),
            mixed_params,
            adafactor_state,
        );
        assert_eq!(
            reference, engine,
            "adafactor(momentum={momentum}) multi-shard engine != sequential"
        );
    }
}

#[test]
fn parity_dense_auto_threads_equals_explicit() {
    // Auto mode on a workload big enough to clear the sequential
    // shortcut must match the explicit 1-thread schedule for every dense
    // optimizer (exactness does not depend on the chosen worker count).
    let hp = Hyper::default();
    let a = run_dense(
        AdamW::new(hp).with_threads(0).with_shard_elems(SHARD_ELEMS),
        big_mixed_params,
        adamw_state,
    );
    let b = run_dense(
        AdamW::new(hp).with_threads(1).with_shard_elems(SHARD_ELEMS),
        big_mixed_params,
        adamw_state,
    );
    assert_eq!(a, b, "adamw32 auto thread count diverged");
    let a = run_dense(
        Sm3::new(hp).with_threads(0).with_shard_elems(SHARD_ELEMS),
        big_mixed_params,
        sm3_state,
    );
    let b = run_dense(
        Sm3::new(hp).with_threads(1).with_shard_elems(SHARD_ELEMS),
        big_mixed_params,
        sm3_state,
    );
    assert_eq!(a, b, "sm3 auto thread count diverged");
}

#[test]
fn parity_auto_threads_equals_explicit() {
    // Auto mode (threads = 0) may choose any worker count; results must
    // match the explicit sequential schedule regardless. The workload is
    // sized above the engine's sequential-shortcut threshold
    // (MIN_PARALLEL_ELEMS) so auto mode actually runs parallel here.
    let total: usize = big_mixed_params()
        .iter()
        .map(|p| p.tensor.numel())
        .sum();
    assert!(
        total >= lowbit_opt::engine::MIN_PARALLEL_ELEMS,
        "test workload ({total} elems) must exceed the sequential shortcut"
    );
    let policy = quantize_everything(QuantPolicy::bit4().stochastic());
    let a = run_params(policy, 0, big_mixed_params);
    let b = run_params(policy, 1, big_mixed_params);
    assert_eq!(a, b, "auto thread count diverged");
}

// ---------------------------------------------------------------------
// Scheduler modes: queue vs sticky are bit-identical at every thread
// count. The sticky scheduler only moves *which worker claims which
// task* (affinity blocks plus bounded stealing); plans, RNG streams and
// reductions are all keyed by task index, so the results may not move
// by a single bit.
// ---------------------------------------------------------------------

use lowbit_opt::engine::SchedMode;
use lowbit_opt::offload::{LinkModel, OffloadConfig};

const SCHEDS: [SchedMode; 2] = [SchedMode::Queue, SchedMode::Sticky];

fn run_sched(
    policy: QuantPolicy,
    mode: SchedMode,
    threads: usize,
    offload_depth: Option<usize>,
) -> RunOut {
    let hp = Hyper::default();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(threads)
        .with_shard_elems(SHARD_ELEMS)
        .with_sched(mode);
    if let Some(depth) = offload_depth {
        opt = opt.offloaded(OffloadConfig::new(LinkModel::pcie_offload(1e-3), depth));
    }
    let mut params = mixed_params();
    for s in 0..STEPS {
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        opt.step(&mut params, &grads, 1e-2);
    }
    RunOut {
        weights: params.iter().map(|p| p.tensor.data.clone()).collect(),
        moments: (0..params.len())
            .map(|i| {
                let (m, v) = opt.moments(i).expect("moments");
                (m.data, v.data)
            })
            .collect(),
        state_bytes: opt.state_bytes(),
    }
}

#[test]
fn parity_sched_modes_adamw4() {
    // SR on, so the claim schedule also may not perturb the per-task RNG
    // streams.
    let policy = || quantize_everything(QuantPolicy::bit4().stochastic());
    let baseline = run_sched(policy(), SchedMode::Queue, 1, None);
    for mode in SCHEDS {
        for &t in &THREADS {
            let out = run_sched(policy(), mode, t, None);
            assert_eq!(
                baseline, out,
                "adamw4 sched={} threads={t} diverged from the sequential queue schedule",
                mode.name()
            );
        }
    }
}

#[test]
fn parity_sched_modes_offloaded_adamw4() {
    // The sticky dependency-queue variant must preserve the offload
    // pipeline's bit-identity too (prefetch depth 2 keeps transfer →
    // compute dependencies live across the claim blocks).
    let policy = || quantize_everything(QuantPolicy::bit4());
    let baseline = run_sched(policy(), SchedMode::Queue, 1, None);
    for mode in SCHEDS {
        for &t in &THREADS {
            let out = run_sched(policy(), mode, t, Some(2));
            assert_eq!(
                baseline, out,
                "offloaded adamw4 sched={} threads={t} diverged from the in-memory schedule",
                mode.name()
            );
        }
    }
}

#[test]
fn parity_sched_modes_dense_adamw32() {
    let hp = Hyper::default();
    let reference = run_dense(AdamW::sequential(hp), mixed_params, adamw_state);
    for mode in SCHEDS {
        for &t in &THREADS {
            let opt = AdamW::new(hp)
                .with_threads(t)
                .with_shard_elems(SHARD_ELEMS)
                .with_sched(mode);
            let out = run_dense(opt, mixed_params, adamw_state);
            assert_eq!(
                reference, out,
                "adamw32 sched={} threads={t} != sequential reference",
                mode.name()
            );
        }
    }
}

#[test]
fn sched_stats_report_mode_and_consistent_counters() {
    // Telemetry sanity at a genuinely parallel thread count (the
    // sequential path never touches the claim tables): every claim is
    // recorded, steals and affinity hits are subsets of claims, the
    // queue reference never steals, and a warm sticky run keeps hitting
    // the learned affinity.
    for mode in SCHEDS {
        let hp = Hyper::default();
        let policy = quantize_everything(QuantPolicy::bit4());
        let mut opt = CompressedAdamW::new(hp, policy)
            .with_threads(2)
            .with_shard_elems(SHARD_ELEMS)
            .with_sched(mode);
        let mut params = mixed_params();
        for s in 0..STEPS {
            let mut grng = Pcg64::seeded(1000 + s as u64);
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
                .collect();
            opt.step(&mut params, &grads, 1e-2);
        }
        let stats = opt.sched_stats().expect("engine-backed optimizer");
        assert_eq!(stats.mode, mode);
        assert!(stats.claims > 0, "sched={}: no claims recorded", mode.name());
        assert!(stats.steals <= stats.claims, "sched={}: steals exceed claims", mode.name());
        assert!(
            stats.affinity_hits <= stats.claims,
            "sched={}: affinity hits exceed claims",
            mode.name()
        );
        if mode == SchedMode::Queue {
            assert_eq!(stats.steals, 0, "the shared-queue reference never steals");
        } else {
            assert!(
                stats.affinity_hits > 0,
                "warm sticky steps should re-claim their learned shards"
            );
        }
    }
}
