//! Determinism parity suite for the shard-parallel step engine: a
//! `CompressedAdamW` stepped at thread counts 1 (the sequential
//! schedule), 2 and 7 must produce **bit-identical** weights and
//! optimizer states — for every quantization policy, with stochastic
//! rounding ON and OFF, factored and quantized second moments, and both
//! 1-D and 2-D parameters.
//!
//! Shard size is forced down to 512 elements so even these small test
//! tensors split into many shards (the 2-D weight into ~5, the 1-D
//! vector into ~12), making the parity check exercise real multi-shard
//! plans rather than trivially passing on single-shard tensors.

use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

const SHARD_ELEMS: usize = 512;
const STEPS: usize = 4;
const THREADS: [usize; 3] = [1, 2, 7];

/// Everything observable about a run: final weights, decompressed
/// moments, and the persistent state footprint.
#[derive(PartialEq, Debug)]
struct RunOut {
    weights: Vec<Vec<f32>>,
    moments: Vec<(Vec<f32>, Vec<f32>)>,
    state_bytes: usize,
}

fn mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(7);
    vec![
        // 2-D, multi-shard under rank-1 row alignment.
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[40, 96], 0.5, &mut rng)),
        // 1-D, multi-shard under B128 alignment.
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[6000], 0.5, &mut rng)),
        // 2-D, two shards.
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        // Tiny tensor, coalesced with whatever shard has room.
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

/// Larger workload (> `MIN_PARALLEL_ELEMS` = 32768 total elements) so
/// auto thread mode genuinely goes parallel instead of short-circuiting
/// to the sequential schedule.
fn big_mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(17);
    vec![
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[64, 384], 0.5, &mut rng)),
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[12000], 0.5, &mut rng)),
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

fn run_params(policy: QuantPolicy, threads: usize, mk: fn() -> Vec<Param>) -> RunOut {
    let hp = Hyper::default();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(threads)
        .with_shard_elems(SHARD_ELEMS);
    let mut params = mk();
    let init: Vec<Vec<f32>> = params.iter().map(|p| p.tensor.data.clone()).collect();
    for s in 0..STEPS {
        // Same gradient stream for every run: re-seeded per step.
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        opt.step(&mut params, &grads, 1e-2);
    }
    // The optimizer must have actually moved the weights.
    for (p, w0) in params.iter().zip(init.iter()) {
        assert_ne!(&p.tensor.data, w0, "{} never updated", p.name);
    }
    RunOut {
        weights: params.iter().map(|p| p.tensor.data.clone()).collect(),
        moments: (0..params.len())
            .map(|i| {
                let (m, v) = opt.moments(i).expect("moments");
                (m.data, v.data)
            })
            .collect(),
        state_bytes: opt.state_bytes(),
    }
}

fn run(policy: QuantPolicy, threads: usize) -> RunOut {
    run_params(policy, threads, mixed_params)
}

fn assert_parity(mk_policy: impl Fn() -> QuantPolicy, label: &str) {
    let baseline = run(mk_policy(), THREADS[0]);
    for &t in &THREADS[1..] {
        let out = run(mk_policy(), t);
        assert_eq!(
            baseline, out,
            "{label}: threads={t} diverged from the sequential schedule"
        );
    }
}

fn quantize_everything(mut policy: QuantPolicy) -> QuantPolicy {
    policy.min_quant_size = 0;
    policy
}

#[test]
fn parity_bit4_deterministic_rounding() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4()),
        "4-bit (m B128/DE, v Rank-1/Linear), SR off",
    );
}

#[test]
fn parity_bit4_stochastic_rounding() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().stochastic()),
        "4-bit, SR on",
    );
}

#[test]
fn parity_bit4_factored() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().factored()),
        "4-bit Factor, SR off",
    );
}

#[test]
fn parity_bit4_factored_stochastic() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit4().factored().stochastic()),
        "4-bit Factor, SR on",
    );
}

#[test]
fn parity_bit8_blockwise() {
    assert_parity(
        || quantize_everything(QuantPolicy::bit8()),
        "8-bit (B2048/DE both moments)",
    );
}

#[test]
fn parity_per_tensor_v() {
    // Per-tensor normalization exercises the global-scale route with a
    // single reduced statistic (and on 1-D tensors too).
    assert_parity(
        || {
            quantize_everything(QuantPolicy::bit4().with_v(Some(Quantizer::new(
                NormKind::PerTensor,
                MapKind::Linear,
                4,
                false,
            ))))
        },
        "4-bit m + per-tensor/Linear v",
    );
}

#[test]
fn parity_fp32_states_match_dense_adamw() {
    // With quantization fully disabled the engine must still be
    // bit-identical to the dense AdamW baseline at every thread count —
    // the update kernel is the same arithmetic, shard split or not.
    let policy = QuantPolicy {
        m_quant: None,
        v_quant: None,
        v_quant_1d: None,
        factor_v: false,
        min_quant_size: 0,
        skip_embedding: false,
    };
    let hp = Hyper::default();
    let mut dense = lowbit_opt::optim::adamw::AdamW::new(hp);
    let mut dense_params = mixed_params();
    for s in 0..STEPS {
        let mut grng = Pcg64::seeded(1000 + s as u64);
        let grads: Vec<Tensor> = dense_params
            .iter()
            .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
            .collect();
        dense.step(&mut dense_params, &grads, 1e-2);
    }
    for &t in &THREADS {
        let mut opt = CompressedAdamW::new(hp, policy)
            .with_threads(t)
            .with_shard_elems(SHARD_ELEMS);
        let mut params = mixed_params();
        for s in 0..STEPS {
            let mut grng = Pcg64::seeded(1000 + s as u64);
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
                .collect();
            opt.step(&mut params, &grads, 1e-2);
        }
        for (a, b) in params.iter().zip(dense_params.iter()) {
            assert_eq!(
                a.tensor.data, b.tensor.data,
                "fp32 engine at {t} threads != dense AdamW for {}",
                a.name
            );
        }
    }
}

#[test]
fn parity_auto_threads_equals_explicit() {
    // Auto mode (threads = 0) may choose any worker count; results must
    // match the explicit sequential schedule regardless. The workload is
    // sized above the engine's sequential-shortcut threshold
    // (MIN_PARALLEL_ELEMS) so auto mode actually runs parallel here.
    let total: usize = big_mixed_params()
        .iter()
        .map(|p| p.tensor.numel())
        .sum();
    assert!(
        total >= lowbit_opt::engine::MIN_PARALLEL_ELEMS,
        "test workload ({total} elems) must exceed the sequential shortcut"
    );
    let policy = quantize_everything(QuantPolicy::bit4().stochastic());
    let a = run_params(policy, 0, big_mixed_params);
    let b = run_params(policy, 1, big_mixed_params);
    assert_eq!(a, b, "auto thread count diverged");
}
