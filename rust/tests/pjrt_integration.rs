//! Integration tests over the PJRT runtime and the AOT artifacts: load
//! the lowered train-step and fused-optimizer HLO, execute them, and
//! cross-check against the native engines. Requires `make artifacts`.

use lowbit_opt::data::MarkovCorpus;
use lowbit_opt::optim::{build, Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::runtime::fused::FusedAdamW4;
use lowbit_opt::runtime::{PjrtTrainStep, Runtime};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn train_step_tiny_executes_and_matches_entropy() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let step = PjrtTrainStep::load(&rt, &dir, "tiny").expect("load artifact");
    let cfg = step.entry.cfg;
    let mut rng = Pcg64::seeded(0);
    let params = cfg.init_params(&mut rng);
    step.check_params(&params).expect("shapes match manifest");

    let corpus = MarkovCorpus::new(cfg.vocab, 1);
    let batch = corpus.sample(step.entry.batch, cfg.max_seq, &mut rng);
    let (loss, grads) = step.step(&params, &batch).expect("execute");
    // Fresh init => loss ~ ln(vocab).
    let uniform = (cfg.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.5,
        "initial PJRT loss {loss} vs ln(V) {uniform}"
    );
    assert_eq!(grads.len(), params.len());
    for (g, p) in grads.iter().zip(params.iter()) {
        assert_eq!(g.shape, p.tensor.shape);
        assert!(!g.any_nonfinite(), "non-finite grad for {}", p.name);
    }
}

#[test]
fn pjrt_grads_agree_with_builtin_engine() {
    // The jax model and the rust builtin transformer implement the same
    // architecture; with identical parameters their losses and gradients
    // must agree to f32 tolerance.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let step = PjrtTrainStep::load(&rt, &dir, "tiny").unwrap();
    let cfg = step.entry.cfg;
    let engine = lowbit_opt::train::TransformerEngine::new(cfg);
    let mut rng = Pcg64::seeded(42);
    let params = cfg.init_params(&mut rng);
    let corpus = MarkovCorpus::new(cfg.vocab, 5);
    let batch = corpus.sample(step.entry.batch, cfg.max_seq, &mut rng);

    let (loss_pjrt, grads_pjrt) = step.step(&params, &batch).unwrap();
    let (loss_native, grads_native) = engine.loss_and_grads(&params, &batch);

    assert!(
        (loss_pjrt - loss_native).abs() < 1e-3,
        "loss mismatch: pjrt {loss_pjrt} native {loss_native}"
    );
    let mut worst = 0.0f32;
    for ((gp, gn), p) in grads_pjrt.iter().zip(grads_native.iter()).zip(params.iter()) {
        for (a, b) in gp.data.iter().zip(gn.data.iter()) {
            let d = (a - b).abs();
            if d > worst {
                worst = d;
            }
            assert!(
                d < 1e-3 + 1e-2 * a.abs().max(b.abs()),
                "grad mismatch in {}: {a} vs {b}",
                p.name
            );
        }
    }
    eprintln!("max grad deviation pjrt vs native: {worst}");
}

#[test]
fn training_through_pjrt_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let step = PjrtTrainStep::load(&rt, &dir, "tiny").unwrap();
    let cfg = step.entry.cfg;
    let mut rng = Pcg64::seeded(7);
    let mut params = cfg.init_params(&mut rng);
    let corpus = MarkovCorpus::new(cfg.vocab, 3);
    let mut opt = build("adamw4", Hyper::default()).unwrap();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..20 {
        let batch = corpus.sample(step.entry.batch, cfg.max_seq, &mut rng);
        let (loss, grads) = step.step(&params, &batch).unwrap();
        first.get_or_insert(loss);
        last = loss;
        opt.step(&mut params, &grads, 2e-3);
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.1,
        "loss should drop through PJRT: {first} -> {last}"
    );
}

#[test]
fn fused_adamw4_matches_native_quantized_path() {
    // The AOT Pallas fused optimizer and the native CompressedAdamW with
    // the equivalent policy (m: B128/DE, v: B128/Linear, no small-tensor
    // rule) must produce closely matching weights on a flat parameter.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let hp = Hyper {
        weight_decay: 0.01,
        ..Hyper::default()
    };
    let mut fused = FusedAdamW4::load(&rt, &dir, hp).expect("load fused artifact");

    let mut policy = lowbit_opt::optim::lowbit::QuantPolicy::bit4();
    policy.min_quant_size = 0;
    policy.m_quant = Some(Quantizer::new(NormKind::Block(128), MapKind::DynExp, 4, true));
    policy.v_quant_1d = Some(Quantizer::new(
        NormKind::Block(128),
        MapKind::Linear,
        4,
        false,
    ));
    let mut native = lowbit_opt::optim::lowbit::CompressedAdamW::new(hp, policy);

    let n = 16384usize; // one fused chunk
    let mut rng = Pcg64::seeded(11);
    let w0 = Tensor::randn(&[n], 0.5, &mut rng);
    let mut p_fused = vec![Param::new("flat", ParamKind::Weight, w0.clone())];
    let mut p_native = vec![Param::new("flat", ParamKind::Weight, w0)];

    for step in 0..5 {
        let g = Tensor::randn(&[n], 0.1, &mut rng);
        fused.step(&mut p_fused, &[g.clone()], 1e-3);
        native.step(&mut p_native, &[g], 1e-3);
        // Same quantizer spec and same math; deviations come from XLA op
        // reordering (e.g. FMA) flipping an occasional 4-bit code at a
        // rounding boundary, which perturbs that coordinate's update by
        // O(lr). Assert the drift is (a) bounded by a few lr per step and
        // (b) rare: almost all coordinates stay within f32 noise.
        let lr = 1e-3f32;
        let mut worst = 0.0f32;
        let mut loose = 0usize;
        for (a, b) in p_fused[0].tensor.data.iter().zip(p_native[0].tensor.data.iter()) {
            let d = (a - b).abs();
            worst = worst.max(d);
            if d > 1e-4 {
                loose += 1;
            }
        }
        assert!(
            worst < 5.0 * lr * (step + 1) as f32,
            "step {step}: fused vs native max deviation {worst}"
        );
        assert!(
            loose < n / 100,
            "step {step}: {loose}/{n} coordinates deviate > 1e-4"
        );
    }
    assert_eq!(fused.t(), 5);
    // Persistent state: 2 states * (n/2 packed bytes + n/128 scales * 4B).
    let expect = 2 * (n / 2 + (n / 128) * 4);
    assert_eq!(fused.state_bytes(), expect);
}
