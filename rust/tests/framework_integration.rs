//! Cross-module integration tests: config → trainer pipeline, checkpoint
//! resume equivalence, determinism, theory (App. H) numerical check, and
//! quantizer fixpoint/monotonicity properties spanning modules.

use lowbit_opt::config::{RawConfig, RunConfig};
use lowbit_opt::data::{ClusterData, LmBatch, MarkovCorpus};
use lowbit_opt::model::MlpConfig;
use lowbit_opt::optim::{build, Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::train::checkpoint::{load_params, save_params};
use lowbit_opt::train::{LrSchedule, MlpEngine, Trainer, TransformerEngine};
use lowbit_opt::util::propcheck;
use lowbit_opt::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Config-driven training pipeline.
// ---------------------------------------------------------------------

#[test]
fn config_to_training_pipeline() {
    let mut raw = RawConfig::parse(
        "[model]\nvocab = 64\nd_model = 32\nn_heads = 2\nd_ff = 64\nn_layers = 1\nmax_seq = 12\n\
         [train]\nsteps = 25\nbatch = 4\n[optimizer]\nname = \"adamw4\"\nlr = 3e-3\n",
    )
    .unwrap();
    raw.set("train.seed=5").unwrap();
    let cfg = RunConfig::from_raw(&raw).unwrap();
    assert_eq!(cfg.model.vocab, 64);

    let engine = TransformerEngine::new(cfg.model);
    let corpus = MarkovCorpus::new(cfg.model.vocab, 9);
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut params = cfg.model.init_params(&mut rng);
    let mut opt = build(&cfg.optimizer, cfg.hyper).unwrap();
    let trainer = Trainer::new(cfg.steps, LrSchedule::Constant(cfg.hyper.lr));
    let mut data_rng = Pcg64::seeded(1);
    let mut f = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    let report = trainer.run(&mut params, opt.as_mut(), &mut f, |_| {
        corpus.sample(cfg.batch, cfg.model.max_seq, &mut data_rng)
    });
    assert!(!report.diverged);
    assert!(report.final_loss < report.losses[0]);
}

// ---------------------------------------------------------------------
// Checkpoint resume: save mid-training, reload, continue — losses of the
// resumed fp32 run must track a straight-through run closely (optimizer
// state is rebuilt, so exact equality is not expected).
// ---------------------------------------------------------------------

#[test]
fn checkpoint_roundtrip_preserves_model_behaviour() {
    let cfg = MlpConfig::tiny();
    let engine = MlpEngine::new(cfg);
    let data = ClusterData::new(cfg.d_in, cfg.n_classes, 3);
    let mut rng = Pcg64::seeded(0);
    let mut params = cfg.init_params(&mut rng);
    let mut opt = build("adamw32", Hyper::default()).unwrap();
    let mut data_rng = Pcg64::seeded(1);
    for _ in 0..30 {
        let b = data.sample(16, &mut data_rng);
        let (_, g) = engine.loss_and_grads(&params, &b);
        opt.step(&mut params, &g, 3e-3);
    }
    let dir = std::env::temp_dir().join(format!("lowbit_it_{}", std::process::id()));
    let path = dir.join("ck").to_str().unwrap().to_string();
    save_params(&path, &params, 30).unwrap();
    let (loaded, step) = load_params(&path).unwrap();
    assert_eq!(step, 30);
    // Identical logits on a fixed batch.
    let mut eval_rng = Pcg64::seeded(7);
    let b = data.sample(32, &mut eval_rng);
    let a1 = engine.accuracy(&params, &b);
    let a2 = engine.accuracy(&loaded, &b);
    assert_eq!(a1, a2);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Determinism: the whole pipeline is seed-deterministic.
// ---------------------------------------------------------------------

#[test]
fn training_is_deterministic_given_seed() {
    let run = || {
        let cfg = MlpConfig::tiny();
        let engine = MlpEngine::new(cfg);
        let data = ClusterData::new(cfg.d_in, cfg.n_classes, 3);
        let mut rng = Pcg64::seeded(4);
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build("adamw4", Hyper::default()).unwrap();
        let mut data_rng = Pcg64::seeded(5);
        let mut last = 0.0;
        for _ in 0..20 {
            let b = data.sample(16, &mut data_rng);
            let (loss, g) = engine.loss_and_grads(&params, &b);
            opt.step(&mut params, &g, 3e-3);
            last = loss;
        }
        (last, params[0].tensor.data.clone())
    };
    let (l1, w1) = run();
    let (l2, w2) = run();
    assert_eq!(l1, l2);
    assert_eq!(w1, w2);
}

// ---------------------------------------------------------------------
// App. H, Theorem 1 numerical check: quantized SGDM on a smooth convex
// quadratic converges to a noise ball whose radius shrinks with the
// quantization variance — 4-bit momentum lands within the bound implied
// by its per-step quantization error, and higher precision lands closer.
// ---------------------------------------------------------------------

#[test]
fn theorem1_noise_ball_ordering() {
    let run = |quantizer: Option<Quantizer>| -> f64 {
        let hp = Hyper {
            beta1: 0.9,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut opt = lowbit_opt::optim::sgdm::Sgdm::new(hp, quantizer);
        let mut rng = Pcg64::seeded(42);
        let target = Tensor::randn(&[64], 1.0, &mut rng);
        let mut params = vec![Param::new("w", ParamKind::Weight, Tensor::zeros(&[64]))];
        // Noisy gradients: g = (w - target) + noise (Assumption 3).
        for _ in 0..500 {
            let mut g = params[0].tensor.sub(&target);
            for v in g.data.iter_mut() {
                *v += rng.normal() * 0.05;
            }
            opt.step(&mut params, &[g], 0.02);
        }
        params[0].tensor.sub(&target).sq_l2()
    };
    let fp32 = run(None);
    let q8 = run(Some(Quantizer::new(
        NormKind::Block(128),
        MapKind::DynExp,
        8,
        true,
    )));
    let q4 = run(Some(Quantizer::first_moment_4bit()));
    // All converge to a small ball; radius ordering follows sigma_m
    // (Theorem 1's alpha*sigma_m^2/(1-beta) term).
    assert!(fp32 < 1.0, "fp32 residual {fp32}");
    assert!(q8 < 1.5, "8-bit residual {q8}");
    assert!(q4 < 3.0, "4-bit residual {q4}");
    assert!(
        fp32 <= q8 * 1.5 && q8 <= q4 * 1.5,
        "noise-ball ordering violated: fp32 {fp32} q8 {q8} q4 {q4}"
    );
}

// ---------------------------------------------------------------------
// Cross-module quantizer properties.
// ---------------------------------------------------------------------

#[test]
fn quantize_is_a_projection_fixpoint_for_unsigned_maps() {
    // For maps whose extremes are representable (unsigned Linear/DE reach
    // 1.0), requantizing a dequantized tensor is the identity: the scale
    // is reattained exactly and every value is a fixed point. NOTE: this
    // is deliberately NOT asserted for the *signed DE* map — it is
    // asymmetric (−1 unrepresentable, App. E.2), so when a block's max
    // magnitude sits on a negative element each requantization contracts
    // the scale by 0.8875; see `signed_de_requantization_contracts`.
    propcheck::check("quant-fixpoint-unsigned", 40, |g| {
        let n = (g.len() * 4).max(4);
        let x = Tensor::from_vec(&[n], g.vec_f32_nonneg(n));
        let q = *g.choose(&[
            Quantizer::second_moment_4bit(),
            Quantizer::new(NormKind::Block(128), MapKind::DynExp, 4, false),
            Quantizer::moment_8bit(false),
        ]);
        let mut rng = Pcg64::seeded(g.case as u64);
        let once = q.quantize(&x, &mut rng).dequantize();
        let twice = q.quantize(&once, &mut rng).dequantize();
        if once.data != twice.data {
            return Err("double quantization moved a representable point".into());
        }
        Ok(())
    });
}

#[test]
fn signed_de_requantization_contracts() {
    // The asymmetric signed DE map can only shrink magnitudes across
    // repeated quantize/dequantize cycles — never grow them (stability of
    // the compressed-optimizer loop depends on this one-sided property).
    propcheck::check("signed-de-contraction", 40, |g| {
        let n = (g.len() * 4).max(4);
        let x = Tensor::from_vec(&[n], g.vec_f32(n));
        let q = Quantizer::first_moment_4bit();
        let mut rng = Pcg64::seeded(g.case as u64);
        let mut cur = x.clone();
        let mut prev_max = f32::INFINITY;
        for _ in 0..4 {
            cur = q.quantize(&cur, &mut rng).dequantize();
            let m = cur.abs_max();
            if m > prev_max * 1.0001 {
                return Err(format!("requantization grew magnitude {prev_max} -> {m}"));
            }
            prev_max = m;
        }
        Ok(())
    });
}

#[test]
fn encode_is_monotone_in_input() {
    // Larger normalized values never map to smaller codes.
    for kind in [MapKind::Linear, MapKind::DynExp, MapKind::DynExpNoZero] {
        for signed in [false, true] {
            let map = lowbit_opt::quant::QuantMap::new(kind, 4, signed);
            let mut prev = 0u8;
            let mut x = if signed { -1.5f32 } else { -0.1 };
            let mut first = true;
            while x <= 1.5 {
                let c = map.encode(x);
                if !first {
                    assert!(c >= prev, "{kind:?} signed={signed}: encode not monotone at {x}");
                }
                prev = c;
                first = false;
                x += 0.003;
            }
        }
    }
}

#[test]
fn optimizer_state_bytes_ordering_on_transformer() {
    // End-to-end ordering across the whole zoo on a realistic model.
    let cfg = lowbit_opt::model::TransformerConfig::tiny();
    let mut rng = Pcg64::seeded(0);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::full(s, 0.01))
        .collect();
    let mut bytes = |preset: &str| -> usize {
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        opt.step(&mut params, &grads, 1e-3);
        opt.state_bytes()
    };
    let b32 = bytes("adamw32");
    let b8 = bytes("adamw8");
    let b4 = bytes("adamw4");
    let bf = bytes("factor4");
    let bafb0 = bytes("adafactor-b0");
    assert!(b32 > b8 && b8 > b4 && b4 > bf, "{b32} {b8} {b4} {bf}");
    assert!(bafb0 < bf, "sublinear adafactor-b0 {bafb0} should be smallest vs {bf}");
}
