//! Step-context cache tests: correctness of cache reuse/invalidation and
//! the zero-allocation guarantee of the steady-state step.
//!
//! * Warm vs cold: an optimizer whose context is invalidated before
//!   every step (cold) must produce bit-identical results to one that
//!   reuses its cache (warm) — caching is a pure optimization.
//! * Rebuild on layout change: driving an executor through one context
//!   with two different models must rebuild the plan (generation bump)
//!   and produce the same bits as a fresh context.
//! * Allocation-free steady state: after warm-up, `step()` performs
//!   **zero** heap allocations for both `adamw32` and `adamw4` at one
//!   thread — the plan, metadata, stat slots, scratch and re-encode
//!   arenas are all cached, and the per-step view vectors recycle their
//!   capacity through the context's `VecArena`.
//!
//! A counting global allocator tallies every allocation in the process,
//! so the tests serialize on one mutex: only the measuring test may run
//! while a measurement is in flight. The optimizers here run with
//! explicit `threads = 1` (the sequential schedule of the same plan) so
//! no pool workers allocate concurrently — except the sticky-scheduler
//! pin, which runs two workers on purpose: the affinity table's claim
//! queues and telemetry are grow-only and must also be allocation-free
//! once warm.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use lowbit_opt::engine::{dense, StepContext, StepEngine};
use lowbit_opt::optim::adamw::AdamW;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

/// Counts every allocation (alloc, alloc_zeroed, realloc) in the
/// process; frees are not counted — the tests pin "no new allocations",
/// which is the cost that scales with plan size.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the tests in this binary so allocation counts are
/// attributable to exactly one test body.
static LOCK: Mutex<()> = Mutex::new(());

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

const SHARD: usize = 1 << 12;
const STEPS: usize = 4;

/// 1-D and 2-D tensors, several shards each at `SHARD`, plus a tiny
/// coalesced bias.
fn model() -> (Vec<Param>, Vec<Tensor>) {
    let mut rng = Pcg64::seeded(91);
    let params = vec![
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[96, 128], 0.5, &mut rng)),
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[9000], 0.5, &mut rng)),
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[64], 0.5, &mut rng)),
    ];
    let mut grng = Pcg64::seeded(17);
    let grads = params
        .iter()
        .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
        .collect();
    (params, grads)
}

fn quantize_everything(mut policy: QuantPolicy) -> QuantPolicy {
    policy.min_quant_size = 0;
    policy
}

// ---------------------------------------------------------------------
// (a) Warm vs cold caches are bit-identical.
// ---------------------------------------------------------------------

#[test]
fn warm_and_cold_caches_step_bit_identically_adamw32() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let (mut p_warm, grads) = model();
    let (mut p_cold, _) = model();

    let mut warm = AdamW::new(hp).with_threads(1).with_shard_elems(SHARD);
    let mut cold = AdamW::new(hp).with_threads(1).with_shard_elems(SHARD);
    for _ in 0..STEPS {
        warm.step(&mut p_warm, &grads, 1e-2);
        // Invalidate before every cold step: the context is rebuilt from
        // scratch each time and must replay the identical plan.
        cold.invalidate_step_cache();
        cold.step(&mut p_cold, &grads, 1e-2);
    }
    for (a, b) in p_warm.iter().zip(p_cold.iter()) {
        assert_eq!(a.tensor.data, b.tensor.data, "warm vs cold diverged: {}", a.name);
    }
    let (ma, va) = warm.moments(0).expect("moments");
    let (mb, vb) = cold.moments(0).expect("moments");
    assert_eq!(ma.data, mb.data);
    assert_eq!(va.data, vb.data);
}

#[test]
fn warm_and_cold_caches_step_bit_identically_adamw4() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let policy = quantize_everything(QuantPolicy::bit4());
    let (mut p_warm, grads) = model();
    let (mut p_cold, _) = model();

    let mut warm = CompressedAdamW::new(hp, policy)
        .with_threads(1)
        .with_shard_elems(SHARD);
    let mut cold = CompressedAdamW::new(hp, policy)
        .with_threads(1)
        .with_shard_elems(SHARD);
    for _ in 0..STEPS {
        warm.step(&mut p_warm, &grads, 1e-2);
        cold.invalidate_step_cache();
        cold.step(&mut p_cold, &grads, 1e-2);
    }
    for (a, b) in p_warm.iter().zip(p_cold.iter()) {
        assert_eq!(a.tensor.data, b.tensor.data, "warm vs cold diverged: {}", a.name);
    }
    assert_eq!(warm.state_bytes(), cold.state_bytes());
    for i in 0..p_warm.len() {
        let (ma, va) = warm.moments(i).expect("moments");
        let (mb, vb) = cold.moments(i).expect("moments");
        assert_eq!(ma.data, mb.data, "m[{i}]");
        assert_eq!(va.data, vb.data, "v[{i}]");
    }
}

// ---------------------------------------------------------------------
// (b) Layout changes rebuild instead of stepping on a stale plan.
// ---------------------------------------------------------------------

fn dense_states(shapes: &[Vec<usize>]) -> (Vec<Param>, Vec<Tensor>, Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Pcg64::seeded(5);
    let params: Vec<Param> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| Param::new(&format!("p{i}"), ParamKind::Weight, Tensor::randn(s, 0.5, &mut rng)))
        .collect();
    let grads = shapes.iter().map(|s| Tensor::randn(s, 0.1, &mut rng)).collect();
    let m = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let v = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    (params, grads, m, v)
}

#[test]
fn shape_and_shard_changes_rebuild_the_context() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let eng = StepEngine::new().with_threads(1).with_shard_elems(256);

    let shapes_a: Vec<Vec<usize>> = vec![vec![12, 48], vec![700]];
    let shapes_b: Vec<Vec<usize>> = vec![vec![12, 48], vec![700], vec![33, 8]];

    // One long-lived context driven across two different models.
    let mut ctx = StepContext::new();
    assert_eq!(ctx.generation(), 0);
    let (mut pa, ga, mut ma, mut va) = dense_states(&shapes_a);
    dense::adamw32_step(&eng, &mut ctx, &hp, 1, 1e-2, &mut pa, &ga, &mut ma, &mut va);
    assert_eq!(ctx.generation(), 1, "first step builds the cache");
    dense::adamw32_step(&eng, &mut ctx, &hp, 2, 1e-2, &mut pa, &ga, &mut ma, &mut va);
    assert_eq!(ctx.generation(), 1, "steady state reuses the cache");

    // Different tensor count/shapes through the same context: must
    // rebuild, and match a fresh-context run bit-for-bit.
    let (mut pb, gb, mut mb, mut vb) = dense_states(&shapes_b);
    dense::adamw32_step(&eng, &mut ctx, &hp, 1, 1e-2, &mut pb, &gb, &mut mb, &mut vb);
    assert_eq!(ctx.generation(), 2, "layout change rebuilds");

    let mut fresh = StepContext::new();
    let (mut pf, gf, mut mf, mut vf) = dense_states(&shapes_b);
    dense::adamw32_step(&eng, &mut fresh, &hp, 1, 1e-2, &mut pf, &gf, &mut mf, &mut vf);
    for (a, b) in pb.iter().zip(pf.iter()) {
        assert_eq!(a.tensor.data, b.tensor.data, "stale-plan corruption on {}", a.name);
    }
    for (a, b) in mb.iter().zip(mf.iter()).chain(vb.iter().zip(vf.iter())) {
        assert_eq!(a.data, b.data);
    }

    // A different shard size through the same context also rebuilds;
    // the elementwise update is exact under any sharding, so results
    // stay identical.
    let eng_small = StepEngine::new().with_threads(1).with_shard_elems(128);
    let (mut pc, gc, mut mc, mut vc) = dense_states(&shapes_b);
    dense::adamw32_step(&eng_small, &mut ctx, &hp, 1, 1e-2, &mut pc, &gc, &mut mc, &mut vc);
    assert_eq!(ctx.generation(), 3, "shard-size change rebuilds");
    for (a, b) in pc.iter().zip(pf.iter()) {
        assert_eq!(a.tensor.data, b.tensor.data, "shard-size dependence on {}", a.name);
    }
}

// ---------------------------------------------------------------------
// (c) The steady-state step allocates nothing.
// ---------------------------------------------------------------------

#[test]
fn steady_state_adamw32_step_is_allocation_free() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let (mut params, grads) = model();
    let mut opt = AdamW::new(hp).with_threads(1).with_shard_elems(SHARD);
    // Warm up: lazy state init, context build, arena capacity growth.
    for _ in 0..3 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let before = allocs();
    for _ in 0..5 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "adamw32 steady-state step allocated {} times over 5 steps",
        after - before
    );
}

#[test]
fn steady_state_adamw4_step_is_allocation_free() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    // bit4 exercises every cached route at once: block-quantized m,
    // rank-1 global v (phase C re-encode + scales recycling) on 2-D
    // tensors, block-quantized 1-D v, and the fp32 small-tensor path.
    let policy = QuantPolicy::bit4();
    let (mut params, grads) = model();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(1)
        .with_shard_elems(SHARD);
    for _ in 0..3 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let before = allocs();
    for _ in 0..5 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "adamw4 steady-state step allocated {} times over 5 steps",
        after - before
    );
}

// Not run under `--features audit`: the auditor keeps lazy per-thread
// call-site caches, and a steady-state steal can route a task to a
// worker that has never executed that `range_mut` site before — a
// one-time auditor allocation, not an engine one.
#[cfg(not(feature = "audit"))]
#[test]
fn steady_state_adamw4_sticky_two_threads_is_allocation_free() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let policy = QuantPolicy::bit4();
    let (mut params, grads) = model();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(2)
        .with_shard_elems(SHARD)
        .with_sched(lowbit_opt::engine::SchedMode::Sticky);
    // Warm up: pool spin-up, context build, affinity-table growth (claim
    // queue, per-worker cursors and telemetry counters are all grow-only).
    for _ in 0..3 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let before = allocs();
    for _ in 0..5 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "sticky 2-thread adamw4 steady-state step allocated {} times over 5 steps",
        after - before
    );
}

#[test]
fn invalidation_spends_allocations_only_on_the_cold_step() {
    let _g = LOCK.lock().unwrap();
    let hp = Hyper::default();
    let (mut params, grads) = model();
    let mut opt = AdamW::new(hp).with_threads(1).with_shard_elems(SHARD);
    for _ in 0..3 {
        opt.step(&mut params, &grads, 1e-3);
    }
    // A cold step after invalidation rebuilds (allocates)...
    opt.invalidate_step_cache();
    let before_cold = allocs();
    opt.step(&mut params, &grads, 1e-3);
    let cold_allocs = allocs() - before_cold;
    assert!(cold_allocs > 0, "cold step must rebuild the context");
    // ...and the very next step is allocation-free again.
    let before_warm = allocs();
    opt.step(&mut params, &grads, 1e-3);
    assert_eq!(allocs() - before_warm, 0, "re-warmed step allocated");
}
