//! Differential kernel-tier suite: every quant kernel tier must be
//! bit-identical to the oracle semantics (`QuantMap::encode`/`decode` +
//! `packing::set`/`get`, and `encode_stochastic` draw-for-draw on the SR
//! paths) on *adversarial* floats — NaN, ±inf, subnormals, `-0.0`,
//! midpoint ties and their ±1-ulp neighbours — across bitwidths, scales
//! and start parities. The scalar tier is pinned against the oracle
//! here; the AVX2 tier is pinned against the scalar tier (on hosts that
//! report AVX2), and the runtime dispatchers against the scalar tier
//! under whatever tier this process resolved.

use lowbit_opt::quant::kernels::{self, scalar};
use lowbit_opt::quant::packing;
use lowbit_opt::quant::stochastic::encode_stochastic;
use lowbit_opt::quant::{MapKind, QuantMap};
use lowbit_opt::util::rng::Pcg64;

fn all_maps() -> Vec<QuantMap> {
    vec![
        QuantMap::new(MapKind::Linear, 4, true),
        QuantMap::new(MapKind::Linear, 4, false),
        QuantMap::new(MapKind::DynExp, 4, true),
        QuantMap::new(MapKind::DynExpNoZero, 4, false),
        QuantMap::new(MapKind::Linear, 8, false),
        QuantMap::new(MapKind::DynExp, 8, true),
    ]
}

fn next_up(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        return x;
    }
    if x == 0.0 {
        return f32::from_bits(1);
    }
    let b = x.to_bits();
    f32::from_bits(if x > 0.0 { b + 1 } else { b - 1 })
}

fn next_down(x: f32) -> f32 {
    -next_up(-x)
}

/// Adversarial normalized inputs for `map`: IEEE edge cases plus every
/// representable value, every adjacent-pair midpoint (the encode tie
/// point) and their ±1-ulp neighbours.
fn adversarial_vals(map: &QuantMap) -> Vec<f32> {
    let mut v = vec![
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0,
        -0.0,
        f32::from_bits(1),
        -f32::from_bits(1),
        f32::from_bits(0x007F_FFFF), // largest subnormal
        f32::MIN_POSITIVE,
        f32::MAX,
        f32::MIN,
        1.0,
        -1.0,
        0.5,
        -0.5,
        1e-30,
        -1e-30,
        1e30,
        -1e30,
    ];
    // encode(+inf) counts every midpoint below it: the top code.
    let top = map.encode(f32::INFINITY);
    for c in 0..=top {
        let a = map.decode(c);
        v.extend([a, next_up(a), next_down(a)]);
        if c < top {
            let b = map.decode(c + 1);
            let mid = ((a as f64 + b as f64) / 2.0) as f32;
            v.extend([mid, next_up(mid), next_down(mid)]);
        }
    }
    v
}

fn rng_streams_synced(a: &mut Pcg64, b: &mut Pcg64) -> bool {
    (0..4).all(|_| a.next_f32().to_bits() == b.next_f32().to_bits())
}

#[test]
fn scalar_run_kernels_match_oracle_on_adversarial_floats() {
    for map in all_maps() {
        let bits = map.bits;
        let vals = adversarial_vals(&map);
        let n = vals.len();
        for s in [1.0f32, 0.25, 3.7] {
            for pos0 in [0usize, 1, 2, 3] {
                let plen = packing::packed_len(pos0 + n, bits);
                let mut dst = vec![0u8; plen];
                scalar::encode_run_scaled(&map, bits, &vals, s, pos0, &mut dst);
                let mut refd = vec![0u8; plen];
                for (k, &v) in vals.iter().enumerate() {
                    packing::set(&mut refd, pos0 + k, map.encode(v / s), bits);
                }
                assert_eq!(dst, refd, "{:?}/{bits} encode s={s} pos0={pos0}", map.kind);

                let mut out = vec![0.0f32; n];
                scalar::decode_run_scaled(&map, bits, &dst, pos0, s, &mut out);
                for (k, &o) in out.iter().enumerate() {
                    let exp = map.decode(packing::get(&dst, pos0 + k, bits)) * s;
                    assert_eq!(
                        o.to_bits(),
                        exp.to_bits(),
                        "{:?}/{bits} decode s={s} pos0={pos0} elem {k}",
                        map.kind
                    );
                }
            }
        }
    }
}

#[test]
fn scalar_rank1_kernels_match_oracle_on_adversarial_floats() {
    // Column scales cycle through degenerate lanes: zero (normalized-0
    // semantics, SR still draws there if the map draws on 0), subnormal,
    // huge, infinite.
    let lanes = [0.0f32, 1.0, f32::MIN_POSITIVE, f32::from_bits(1), 1e30, f32::INFINITY, 0.5];
    for map in all_maps() {
        let bits = map.bits;
        let vals = adversarial_vals(&map);
        let n = vals.len();
        let cseg: Vec<f32> = (0..n).map(|k| lanes[k % lanes.len()]).collect();
        for ri in [1.0f32, 0.0, 2.5, f32::INFINITY] {
            for pos0 in [0usize, 1, 3] {
                let plen = packing::packed_len(pos0 + n, bits);
                let mut dst = vec![0u8; plen];
                scalar::encode_rank1_row(&map, bits, &vals, ri, &cseg, pos0, &mut dst);
                let mut refd = vec![0u8; plen];
                for (k, &v) in vals.iter().enumerate() {
                    let cj = cseg[k];
                    let s = if ri < cj { ri } else { cj };
                    let nrm = if s > 0.0 { v / s } else { 0.0 };
                    packing::set(&mut refd, pos0 + k, map.encode(nrm), bits);
                }
                assert_eq!(dst, refd, "{:?}/{bits} rank1 ri={ri} pos0={pos0}", map.kind);

                let mut out = vec![0.0f32; n];
                scalar::decode_rank1_row(&map, bits, &dst, pos0, ri, &cseg, &mut out);
                for (k, &o) in out.iter().enumerate() {
                    let cj = cseg[k];
                    let s = if ri < cj { ri } else { cj };
                    let exp = map.decode(packing::get(&dst, pos0 + k, bits)) * s;
                    assert_eq!(
                        o.to_bits(),
                        exp.to_bits(),
                        "{:?}/{bits} rank1 decode ri={ri} pos0={pos0} elem {k}",
                        map.kind
                    );
                }
            }
        }
    }
}

#[test]
fn scalar_sr_kernels_match_unfused_loop_bytes_and_draws() {
    for map in all_maps() {
        let bits = map.bits;
        let vals = adversarial_vals(&map);
        let n = vals.len();
        for pos0 in [0usize, 1, 2, 3] {
            let plen = packing::packed_len(pos0 + n, bits);
            let s = 0.75f32;

            let mut dst = vec![0u8; plen];
            let mut rng_a = Pcg64::seeded(42);
            scalar::encode_sr_run_scaled(&map, bits, &vals, s, pos0, &mut dst, &mut rng_a);
            let mut refd = vec![0u8; plen];
            let mut rng_b = Pcg64::seeded(42);
            for (k, &v) in vals.iter().enumerate() {
                let code = encode_stochastic(&map, v / s, &mut rng_b);
                packing::set(&mut refd, pos0 + k, code, bits);
            }
            assert_eq!(dst, refd, "{:?}/{bits} SR run pos0={pos0}", map.kind);
            assert!(
                rng_streams_synced(&mut rng_a, &mut rng_b),
                "{:?}/{bits} SR run pos0={pos0}: RNG stream diverged",
                map.kind
            );

            let cseg: Vec<f32> = (0..n).map(|k| [1.0f32, 0.0, 0.5, 2.0][k % 4]).collect();
            let ri = 1.5f32;
            let mut dst = vec![0u8; plen];
            let mut rng_a = Pcg64::seeded(7);
            scalar::encode_sr_rank1_row(&map, bits, &vals, ri, &cseg, pos0, &mut dst, &mut rng_a);
            let mut refd = vec![0u8; plen];
            let mut rng_b = Pcg64::seeded(7);
            for (k, &v) in vals.iter().enumerate() {
                let cj = cseg[k];
                let sc = if ri < cj { ri } else { cj };
                let nrm = if sc > 0.0 { v / sc } else { 0.0 };
                packing::set(&mut refd, pos0 + k, encode_stochastic(&map, nrm, &mut rng_b), bits);
            }
            assert_eq!(dst, refd, "{:?}/{bits} SR rank1 pos0={pos0}", map.kind);
            assert!(
                rng_streams_synced(&mut rng_a, &mut rng_b),
                "{:?}/{bits} SR rank1 pos0={pos0}: RNG stream diverged",
                map.kind
            );
        }
    }
}

#[test]
fn sr_nan_matches_nearest_and_consumes_no_draw() {
    // The crash-regression pin: NaN under SR must behave exactly like
    // deterministic encode — code 0 via the degenerate bracket — and
    // must not consume an RNG draw (thread-count invariance depends on
    // the draw schedule being value-independent only through brackets).
    for map in all_maps() {
        assert_eq!(map.bracket(f32::NAN), (0, 0), "{:?}/{}", map.kind, map.bits);
        let mut rng = Pcg64::seeded(3);
        let before = rng.next_f32().to_bits();
        let mut rng = Pcg64::seeded(3);
        let code = encode_stochastic(&map, f32::NAN, &mut rng);
        assert_eq!(code, map.encode(f32::NAN), "{:?}/{}", map.kind, map.bits);
        assert_eq!(code, 0);
        assert_eq!(
            rng.next_f32().to_bits(),
            before,
            "{:?}/{}: NaN consumed an RNG draw",
            map.kind,
            map.bits
        );
    }
}

#[test]
fn scalar_ema_kernels_match_unfused_reference() {
    // Fused in-place decode→EMA→re-encode vs the unfused reference
    // (oracle decode, scalar EMA expression, oracle encode), with
    // adversarial gradients (NaN, ±inf, subnormals) folded in.
    for map in all_maps() {
        let bits = map.bits;
        let base = adversarial_vals(&map);
        let n = base.len();
        let g: Vec<f32> = (0..n).map(|k| base[(k * 7 + 3) % n]).collect();
        let (old_s, new_s) = (1.5f32, 0.8f32);
        for pos0 in [0usize, 1, 2, 3] {
            for second in [false, true] {
                for stochastic in [false, true] {
                    let beta = 0.9f32;
                    let plen = packing::packed_len(pos0 + n, bits);
                    let mut img = vec![0u8; plen];
                    scalar::encode_run_scaled(&map, bits, &base, old_s, pos0, &mut img);

                    let mut fused = img.clone();
                    let mut rng_a = Pcg64::seeded(11);
                    scalar::ema_reencode_run_scaled(
                        &map, bits, &mut fused, pos0, old_s, new_s, &g, beta, second, stochastic,
                        &mut rng_a,
                    );

                    let mut refd = img.clone();
                    let mut rng_b = Pcg64::seeded(11);
                    for (k, &gv) in g.iter().enumerate() {
                        let x = map.decode(packing::get(&img, pos0 + k, bits)) * old_s;
                        let e = if second {
                            beta * x + (1.0 - beta) * gv * gv
                        } else {
                            beta * x + (1.0 - beta) * gv
                        };
                        let code = if stochastic {
                            encode_stochastic(&map, e / new_s, &mut rng_b)
                        } else {
                            map.encode(e / new_s)
                        };
                        packing::set(&mut refd, pos0 + k, code, bits);
                    }
                    assert_eq!(
                        fused, refd,
                        "{:?}/{bits} EMA run pos0={pos0} second={second} sr={stochastic}",
                        map.kind
                    );
                    assert!(
                        rng_streams_synced(&mut rng_a, &mut rng_b),
                        "{:?}/{bits} EMA run pos0={pos0}: RNG stream diverged",
                        map.kind
                    );
                }
            }
        }
    }
}

#[test]
fn dispatched_kernels_match_scalar_tier() {
    // Whatever tier this process resolved (auto unless the environment
    // forces one), the public dispatchers must agree with the scalar
    // tier bit-for-bit — this is the end-to-end dispatch pin.
    for map in all_maps() {
        let bits = map.bits;
        let vals = adversarial_vals(&map);
        let n = vals.len();
        let s = 1.25f32;
        for pos0 in [0usize, 1, 3] {
            let plen = packing::packed_len(pos0 + n, bits);

            let mut a = vec![0u8; plen];
            kernels::encode_run_scaled(&map, bits, &vals, s, pos0, &mut a);
            let mut b = vec![0u8; plen];
            scalar::encode_run_scaled(&map, bits, &vals, s, pos0, &mut b);
            assert_eq!(a, b, "{:?}/{bits} dispatched encode pos0={pos0}", map.kind);

            let mut oa = vec![0.0f32; n];
            kernels::decode_run_scaled(&map, bits, &a, pos0, s, &mut oa);
            let mut ob = vec![0.0f32; n];
            scalar::decode_run_scaled(&map, bits, &b, pos0, s, &mut ob);
            let same = oa
                .iter()
                .zip(ob.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "{:?}/{bits} dispatched decode pos0={pos0}", map.kind);

            let mut a = vec![0u8; plen];
            let mut rng_a = Pcg64::seeded(5);
            kernels::encode_sr_run_scaled(&map, bits, &vals, s, pos0, &mut a, &mut rng_a);
            let mut b = vec![0u8; plen];
            let mut rng_b = Pcg64::seeded(5);
            scalar::encode_sr_run_scaled(&map, bits, &vals, s, pos0, &mut b, &mut rng_b);
            assert_eq!(a, b, "{:?}/{bits} dispatched SR pos0={pos0}", map.kind);
            assert!(rng_streams_synced(&mut rng_a, &mut rng_b));
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2_vs_scalar {
    use super::*;
    use lowbit_opt::quant::kernels::avx2;

    /// Runs `f` only when the host actually reports AVX2; the wrappers
    /// in `kernels::avx2` would otherwise be undefined to vector-path.
    fn with_avx2(f: impl FnOnce()) {
        if std::arch::is_x86_feature_detected!("avx2") {
            f();
        } else {
            eprintln!("host lacks AVX2; skipping SIMD-vs-scalar differential");
        }
    }

    #[test]
    fn avx2_run_kernels_match_scalar_on_adversarial_floats() {
        with_avx2(|| {
            for map in all_maps() {
                let bits = map.bits;
                let vals = adversarial_vals(&map);
                let n = vals.len();
                for s in [1.0f32, 0.33] {
                    for pos0 in [0usize, 1, 2, 3] {
                        let plen = packing::packed_len(pos0 + n, bits);
                        let mut a = vec![0u8; plen];
                        avx2::encode_run_scaled(&map, bits, &vals, s, pos0, &mut a);
                        let mut b = vec![0u8; plen];
                        scalar::encode_run_scaled(&map, bits, &vals, s, pos0, &mut b);
                        assert_eq!(a, b, "{:?}/{bits} avx2 encode s={s} pos0={pos0}", map.kind);

                        let mut oa = vec![0.0f32; n];
                        avx2::decode_run_scaled(&map, bits, &a, pos0, s, &mut oa);
                        let mut ob = vec![0.0f32; n];
                        scalar::decode_run_scaled(&map, bits, &b, pos0, s, &mut ob);
                        for (k, (x, y)) in oa.iter().zip(ob.iter()).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{:?}/{bits} avx2 decode s={s} pos0={pos0} elem {k}",
                                map.kind
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn avx2_rank1_kernels_match_scalar_on_adversarial_floats() {
        with_avx2(|| {
            let lanes = [0.0f32, 1.0, f32::MIN_POSITIVE, 1e30, f32::INFINITY, 0.5];
            for map in all_maps() {
                let bits = map.bits;
                let vals = adversarial_vals(&map);
                let n = vals.len();
                let cseg: Vec<f32> = (0..n).map(|k| lanes[k % lanes.len()]).collect();
                for ri in [1.0f32, 0.0, f32::INFINITY] {
                    for pos0 in [0usize, 1, 3] {
                        let plen = packing::packed_len(pos0 + n, bits);
                        let mut a = vec![0u8; plen];
                        avx2::encode_rank1_row(&map, bits, &vals, ri, &cseg, pos0, &mut a);
                        let mut b = vec![0u8; plen];
                        scalar::encode_rank1_row(&map, bits, &vals, ri, &cseg, pos0, &mut b);
                        assert_eq!(a, b, "{:?}/{bits} avx2 rank1 ri={ri} pos0={pos0}", map.kind);

                        let mut oa = vec![0.0f32; n];
                        avx2::decode_rank1_row(&map, bits, &a, pos0, ri, &cseg, &mut oa);
                        let mut ob = vec![0.0f32; n];
                        scalar::decode_rank1_row(&map, bits, &b, pos0, ri, &cseg, &mut ob);
                        for (k, (x, y)) in oa.iter().zip(ob.iter()).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{:?}/{bits} avx2 rank1 decode ri={ri} pos0={pos0} elem {k}",
                                map.kind
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn avx2_sr_kernels_match_scalar_bytes_and_draws() {
        with_avx2(|| {
            for map in all_maps() {
                let bits = map.bits;
                let vals = adversarial_vals(&map);
                let n = vals.len();
                let cseg: Vec<f32> = (0..n).map(|k| [1.0f32, 0.0, 0.5, 2.0][k % 4]).collect();
                for pos0 in [0usize, 1, 2, 3] {
                    let plen = packing::packed_len(pos0 + n, bits);
                    let s = 0.6f32;

                    let mut a = vec![0u8; plen];
                    let mut rng_a = Pcg64::seeded(13);
                    avx2::encode_sr_run_scaled(&map, bits, &vals, s, pos0, &mut a, &mut rng_a);
                    let mut b = vec![0u8; plen];
                    let mut rng_b = Pcg64::seeded(13);
                    scalar::encode_sr_run_scaled(&map, bits, &vals, s, pos0, &mut b, &mut rng_b);
                    assert_eq!(a, b, "{:?}/{bits} avx2 SR run pos0={pos0}", map.kind);
                    assert!(
                        rng_streams_synced(&mut rng_a, &mut rng_b),
                        "{:?}/{bits} avx2 SR run pos0={pos0}: RNG diverged",
                        map.kind
                    );

                    let mut a = vec![0u8; plen];
                    let mut rng_a = Pcg64::seeded(17);
                    avx2::encode_sr_rank1_row(
                        &map, bits, &vals, 1.5, &cseg, pos0, &mut a, &mut rng_a,
                    );
                    let mut b = vec![0u8; plen];
                    let mut rng_b = Pcg64::seeded(17);
                    scalar::encode_sr_rank1_row(
                        &map, bits, &vals, 1.5, &cseg, pos0, &mut b, &mut rng_b,
                    );
                    assert_eq!(a, b, "{:?}/{bits} avx2 SR rank1 pos0={pos0}", map.kind);
                    assert!(
                        rng_streams_synced(&mut rng_a, &mut rng_b),
                        "{:?}/{bits} avx2 SR rank1 pos0={pos0}: RNG diverged",
                        map.kind
                    );
                }
            }
        });
    }

    #[test]
    fn avx2_ema_kernels_match_scalar_bytes_and_draws() {
        with_avx2(|| {
            let lanes = [0.0f32, 1.0, 0.25, 4.0, 1e-20, 1e20];
            for map in all_maps() {
                let bits = map.bits;
                let base = adversarial_vals(&map);
                let n = base.len();
                let g: Vec<f32> = (0..n).map(|k| base[(k * 11 + 5) % n]).collect();
                let (old_s, new_s) = (2.0f32, 0.7f32);
                for pos0 in [0usize, 1, 2, 3] {
                    for second in [false, true] {
                        for stochastic in [false, true] {
                            let beta = if second { 0.99f32 } else { 0.9 };
                            let plen = packing::packed_len(pos0 + n, bits);
                            let mut img = vec![0u8; plen];
                            scalar::encode_run_scaled(&map, bits, &base, old_s, pos0, &mut img);

                            let mut a = img.clone();
                            let mut rng_a = Pcg64::seeded(19);
                            avx2::ema_reencode_run_scaled(
                                &map, bits, &mut a, pos0, old_s, new_s, &g, beta, second,
                                stochastic, &mut rng_a,
                            );
                            let mut b = img.clone();
                            let mut rng_b = Pcg64::seeded(19);
                            scalar::ema_reencode_run_scaled(
                                &map, bits, &mut b, pos0, old_s, new_s, &g, beta, second,
                                stochastic, &mut rng_b,
                            );
                            assert_eq!(
                                a, b,
                                "{:?}/{bits} avx2 EMA run pos0={pos0} second={second} \
                                 sr={stochastic}",
                                map.kind
                            );
                            assert!(rng_streams_synced(&mut rng_a, &mut rng_b));

                            // Rank-1 form over the same image.
                            let ocseg: Vec<f32> =
                                (0..n).map(|k| lanes[k % lanes.len()]).collect();
                            let ncseg: Vec<f32> =
                                (0..n).map(|k| lanes[(k + 2) % lanes.len()]).collect();
                            let mut a = img.clone();
                            let mut rng_a = Pcg64::seeded(23);
                            avx2::ema_reencode_rank1_row(
                                &map, bits, &mut a, pos0, 1.2, &ocseg, 0.9, &ncseg, &g, beta,
                                second, stochastic, &mut rng_a,
                            );
                            let mut b = img.clone();
                            let mut rng_b = Pcg64::seeded(23);
                            scalar::ema_reencode_rank1_row(
                                &map, bits, &mut b, pos0, 1.2, &ocseg, 0.9, &ncseg, &g, beta,
                                second, stochastic, &mut rng_b,
                            );
                            assert_eq!(
                                a, b,
                                "{:?}/{bits} avx2 EMA rank1 pos0={pos0} second={second} \
                                 sr={stochastic}",
                                map.kind
                            );
                            assert!(rng_streams_synced(&mut rng_a, &mut rng_b));
                        }
                    }
                }
            }
        });
    }
}
