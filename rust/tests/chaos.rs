//! Chaos suite: the fault-injection / retry / recovery acceptance tests.
//!
//! 1. **Bit-identity under link faults**: offloaded `adamw4` runs with
//!    seeded transfer failures and payload corruption are bit-identical
//!    to the fault-free in-memory run at threads 1/2/7 × depths 1/2/4 —
//!    retries replay identical bytes and corruption is caught by the
//!    per-transfer CRC before any kernel reads it. Retry counters are
//!    nonzero and *identical* across every thread count and depth (the
//!    fault schedule is keyed by logical coordinates, not wall time).
//! 2. **Step atomicity**: a scheduled mid-step worker panic aborts the
//!    step, `try_step` rolls back, and the retried run — weights, packed
//!    codes, scales, step counter — is bit-identical to a never-faulted
//!    one, with the rollback counted in the step report.
//! 3. **Checkpoint integrity** (property): a checkpoint truncated at
//!    *every* section boundary (and mid-section) is rejected with an
//!    error naming a section — never loaded, never a panic.
//!
//! Under `--features audit` the same sweeps double as a false-alarm
//! check: the retry loop's checksum views and the staging copies live in
//! the same transfer task, which the aliasing auditor must accept.

use lowbit_opt::fault::{crc32, Crc32, FaultKind, FaultPlan, Phase};
use lowbit_opt::offload::{LinkModel, OffloadConfig};
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::state::{MomentState, SecondState};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::quant::Scales;
use lowbit_opt::tensor::Tensor;
use lowbit_opt::train::checkpoint::{load_opt_state, save_opt_state};
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

const SHARD_ELEMS: usize = 512;
const STEPS: usize = 4;
const THREADS: [usize; 3] = [1, 2, 7];
const DEPTHS: [usize; 3] = [1, 2, 4];

fn mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(7);
    vec![
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[40, 96], 0.5, &mut rng)),
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[6000], 0.5, &mut rng)),
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

fn step_grads(params: &[Param], s: usize) -> Vec<Tensor> {
    let mut grng = Pcg64::seeded(1000 + s as u64);
    params
        .iter()
        .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
        .collect()
}

fn any_link() -> LinkModel {
    LinkModel::pcie_offload(1e-3)
}

fn bit4_all() -> QuantPolicy {
    let mut p = QuantPolicy::bit4();
    p.min_quant_size = 0;
    p
}

/// CRC fingerprint of everything a step mutates: weights, the exact
/// packed codes + scales of every state, and the step counter. Equal
/// fingerprints mean bit-identical runs (stronger than comparing
/// decompressed moments — it pins the stored bytes themselves).
fn fingerprint(opt: &CompressedAdamW, params: &[Param]) -> Vec<u32> {
    fn f32s(vals: &[f32]) -> u32 {
        let mut c = Crc32::new();
        c.update_f32s(vals);
        c.finish()
    }
    fn scales(out: &mut Vec<u32>, s: &Scales) {
        match s {
            Scales::PerTensor(x) => out.push(x.to_bits()),
            Scales::Block { scales, .. } => out.push(f32s(scales)),
            Scales::Rank1 { per_axis } => {
                for axis in per_axis {
                    out.push(f32s(axis));
                }
            }
        }
    }
    let (t, ms, vs) = opt.export_states();
    let mut out = vec![t as u32];
    for p in params {
        out.push(f32s(&p.tensor.data));
    }
    for m in ms {
        match m {
            MomentState::F32(tn) => out.push(f32s(&tn.data)),
            MomentState::Quant(q) => {
                out.push(crc32(&q.packed));
                scales(&mut out, &q.scales);
            }
        }
    }
    for v in vs {
        match v {
            SecondState::F32(tn) => out.push(f32s(&tn.data)),
            SecondState::Quant(q) => {
                out.push(crc32(&q.packed));
                scales(&mut out, &q.scales);
            }
            SecondState::Factored(f) => {
                out.push(f32s(&f.row));
                out.push(f32s(&f.col));
            }
        }
    }
    out
}

/// In-memory run: no offload pipeline, hence no fault sites at all —
/// the fault-free reference even when `LOWBIT_FAULTS` is set.
fn baseline(policy: QuantPolicy) -> Vec<u32> {
    let mut opt = CompressedAdamW::new(Hyper::default(), policy)
        .with_threads(1)
        .with_shard_elems(SHARD_ELEMS);
    let mut params = mixed_params();
    for s in 0..STEPS {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    fingerprint(&opt, &params)
}

fn faulted_opt(policy: QuantPolicy, threads: usize, depth: usize, plan: FaultPlan) -> CompressedAdamW {
    CompressedAdamW::new(Hyper::default(), policy)
        .with_threads(threads)
        .with_shard_elems(SHARD_ELEMS)
        .offloaded(OffloadConfig::new(any_link(), depth))
        .with_faults(plan)
}

#[test]
fn link_faults_keep_bit_identity_across_threads_depths_and_rates() {
    let reference = baseline(bit4_all());
    for kind in [FaultKind::Fail, FaultKind::Corrupt, FaultKind::Mixed] {
        for rate in [0.05, 0.25] {
            // (retries, fail, corrupt, virtual seconds bits) of the first
            // combo; every other thread × depth combo must match exactly —
            // the schedule is keyed by (step, phase, task), never by who
            // ran it or how deep the prefetch pipeline was.
            let mut pinned: Option<(u64, u64, f64)> = None;
            for &t in &THREADS {
                for &d in &DEPTHS {
                    let plan = FaultPlan::new(0xC0FFEE).with_rate(rate).with_kind(kind);
                    let mut opt = faulted_opt(bit4_all(), t, d, plan);
                    let mut params = mixed_params();
                    for s in 0..STEPS {
                        let grads = step_grads(&params, s);
                        opt.step(&mut params, &grads, 1e-2);
                    }
                    assert_eq!(
                        reference,
                        fingerprint(&opt, &params),
                        "faulted run diverged: kind {kind:?} rate {rate} threads {t} depth {d}"
                    );
                    let rep = opt.offload_report().expect("offloaded").clone();
                    let retries = rep.retries();
                    assert!(
                        retries > 0,
                        "rate {rate} {kind:?} rolled no faults over {STEPS} steps"
                    );
                    match kind {
                        FaultKind::Fail => assert_eq!(rep.corrupt_retries, 0),
                        FaultKind::Corrupt => {
                            // Writeback faults degrade to Fail; stage-in
                            // corruption must actually fire too.
                            assert!(rep.corrupt_retries > 0);
                        }
                        FaultKind::Mixed => {}
                    }
                    assert!(rep.retry_seconds > 0.0, "retries must cost virtual time");
                    match pinned {
                        None => pinned = Some((rep.fail_retries, rep.corrupt_retries, rep.retry_seconds)),
                        Some((f0, c0, s0)) => {
                            assert_eq!(
                                (f0, c0, s0.to_bits()),
                                (rep.fail_retries, rep.corrupt_retries, rep.retry_seconds.to_bits()),
                                "retry accounting must be schedule-independent \
                                 (kind {kind:?} rate {rate} threads {t} depth {d})"
                            );
                        }
                    }
                    // The unified report carries the same counters.
                    let sr = opt.step_report().expect("compressed optimizer reports");
                    let fc = sr.faults.expect("fault counters always present");
                    assert_eq!(fc.retries(), retries);
                    assert_eq!(fc.rollbacks, 0);
                }
            }
        }
    }
}

#[test]
fn stochastic_rounding_survives_faults_bit_identically() {
    // SR draws from per-shard RNG streams during phase C; replayed
    // transfers must not shift a single draw.
    let policy = || {
        let mut p = QuantPolicy::bit4().stochastic();
        p.min_quant_size = 0;
        p
    };
    let reference = baseline(policy());
    let plan = || FaultPlan::new(7).with_rate(0.25).with_kind(FaultKind::Mixed);
    for &t in &THREADS {
        let mut opt = faulted_opt(policy(), t, 2, plan());
        let mut params = mixed_params();
        for s in 0..STEPS {
            let grads = step_grads(&params, s);
            opt.step(&mut params, &grads, 1e-2);
        }
        assert_eq!(reference, fingerprint(&opt, &params), "SR diverged at threads {t}");
    }
}

#[test]
fn heavy_corruption_recovers_without_audit_alarms() {
    // A corruption-heavy sweep: every retry runs the CRC views and the
    // staging copies in the same transfer task, which the aliasing
    // auditor (when this suite is compiled with `--features audit`)
    // must accept without a false alarm — and the run must still be
    // bit-identical.
    let reference = baseline(bit4_all());
    let plan = FaultPlan::new(99).with_rate(0.45).with_kind(FaultKind::Corrupt);
    let mut opt = faulted_opt(bit4_all(), 7, 4, plan);
    let mut params = mixed_params();
    for s in 0..STEPS {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    assert_eq!(reference, fingerprint(&opt, &params));
    assert!(opt.offload_report().expect("offloaded").corrupt_retries > 10);
}

#[test]
fn env_gated_faults_keep_bit_identity() {
    // No builder override here: the pipeline falls back to the process
    // `LOWBIT_FAULTS` gate. Under ci.sh's pinned schedule this exercises
    // the env path end to end; with the variable unset it is a clean
    // offloaded run. Either way the result is bit-identical to the
    // in-memory reference.
    let reference = baseline(bit4_all());
    let mut opt = CompressedAdamW::new(Hyper::default(), bit4_all())
        .with_threads(2)
        .with_shard_elems(SHARD_ELEMS)
        .offloaded(OffloadConfig::new(any_link(), 2));
    let mut params = mixed_params();
    for s in 0..STEPS {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    assert_eq!(reference, fingerprint(&opt, &params));
}

#[test]
fn pinned_none_plan_overrides_the_env_gate() {
    // FaultPlan::none() pins a run fault-free even when LOWBIT_FAULTS
    // is set: zero retries, bit-identical, trivially.
    let reference = baseline(bit4_all());
    let mut opt = faulted_opt(bit4_all(), 2, 2, FaultPlan::none());
    let mut params = mixed_params();
    for s in 0..STEPS {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    assert_eq!(reference, fingerprint(&opt, &params));
    assert_eq!(opt.offload_report().expect("offloaded").retries(), 0);
}

// ---------------------------------------------------------------------
// Step atomicity: scheduled worker panics, rollback, retry.
// ---------------------------------------------------------------------

/// Drive `opt` through the standard run, retrying any aborted step.
/// Returns how many aborts were observed.
fn run_with_retries(opt: &mut CompressedAdamW, params: &mut [Param]) -> usize {
    let mut aborts = 0;
    for s in 0..STEPS {
        let grads = step_grads(params, s);
        loop {
            match opt.try_step(params, &grads, 1e-2) {
                Ok(()) => break,
                Err(e) => {
                    aborts += 1;
                    // The injected message survives when the panicking
                    // task ran on the submitter; a pool worker's unwind
                    // is re-raised under the engine's generic banner.
                    assert!(
                        e.message.contains("injected fault")
                            || e.message.contains("engine worker panicked"),
                        "unexpected abort cause: {}",
                        e.message
                    );
                    assert!(aborts < 16, "rollback retry did not converge");
                }
            }
        }
    }
    aborts
}

#[test]
fn mid_step_panic_rolls_back_and_retries_bit_identically() {
    let reference = baseline(bit4_all());
    for (phase, task) in [(Phase::A, 1), (Phase::A, 0), (Phase::C, 0)] {
        for &t in &THREADS {
            // Panic on the third step; the one-shot trigger lets the
            // post-rollback retry of that same step run clean.
            let plan = FaultPlan::new(0xABAD).panic_at(3, phase, task);
            let mut opt = faulted_opt(bit4_all(), t, 2, plan);
            let mut params = mixed_params();
            let aborts = run_with_retries(&mut opt, &mut params);
            assert_eq!(aborts, 1, "exactly one abort at {phase:?}/{task} threads {t}");
            assert_eq!(opt.rollbacks(), 1);
            assert_eq!(
                reference,
                fingerprint(&opt, &params),
                "post-rollback retry diverged at {phase:?}/{task} threads {t}"
            );
            let fc = opt.step_report().expect("report").faults.expect("counters");
            assert_eq!(fc.rollbacks, 1);
        }
    }
}

#[test]
fn acceptance_link_faults_plus_mid_step_panic() {
    // The issue's acceptance schedule: link failures at a nonzero rate
    // AND one mid-step worker panic. The run completes, is bit-identical
    // to the fault-free reference, and the step report carries nonzero
    // retry and rollback counters.
    let reference = baseline(bit4_all());
    let plan = FaultPlan::new(0xFA11)
        .with_rate(0.1)
        .with_kind(FaultKind::Mixed)
        .panic_at(2, Phase::A, 0);
    let mut opt = faulted_opt(bit4_all(), 7, 2, plan);
    let mut params = mixed_params();
    let aborts = run_with_retries(&mut opt, &mut params);
    assert_eq!(aborts, 1);
    assert_eq!(reference, fingerprint(&opt, &params));
    let fc = opt.step_report().expect("report").faults.expect("counters");
    assert!(fc.retries() > 0, "link faults must have fired");
    assert_eq!(fc.rollbacks, 1, "the panic must have rolled back once");
    assert!(fc.retry_virtual_seconds > 0.0);
}

#[test]
fn pool_is_reusable_after_an_uncaught_abort() {
    // Even without try_step, a panicked step must leave the engine pool
    // and the optimizer's buffers in a state where a *fresh* optimizer
    // sharing nothing still works — and the panicked instance itself can
    // continue after the one-shot trigger fired (its state is torn, but
    // stepping must not hang or double-panic).
    let plan = FaultPlan::new(5).panic_at(1, Phase::A, 0);
    let mut opt = faulted_opt(bit4_all(), 2, 2, plan);
    let mut params = mixed_params();
    let grads = step_grads(&params, 0);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        opt.step(&mut params, &grads, 1e-2);
    }));
    assert!(r.is_err(), "scheduled panic must propagate through step()");
    // The same instance steps again (trigger is one-shot).
    opt.invalidate_step_cache();
    opt.step(&mut params, &grads, 1e-2);
    assert_eq!(opt.t(), 2, "both steps counted (no rollback without try_step)");
}

// ---------------------------------------------------------------------
// Checkpoint integrity property test.
// ---------------------------------------------------------------------

#[test]
fn checkpoint_truncated_at_every_section_boundary_is_rejected() {
    // Save a checkpoint holding every state form (f32 below the size
    // threshold, quantized, factored), then truncate the blob at every
    // section boundary and mid-section. Every cut must be rejected with
    // an error naming a section; the intact file must still load.
    let hp = Hyper::default();
    let mut policy = QuantPolicy::bit4().factored();
    policy.min_quant_size = 1000;
    let mut opt = CompressedAdamW::new(hp, policy);
    let mut params = mixed_params();
    for s in 0..2 {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    let dir = std::env::temp_dir().join(format!("lowbit_chaos_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("opt").to_str().unwrap().to_string();
    save_opt_state(&path, &opt).unwrap();

    let manifest = Json::parse(&std::fs::read_to_string(format!("{path}.json")).unwrap()).unwrap();
    let states = manifest.get("states").and_then(|s| s.as_arr()).unwrap();
    assert!(states.len() >= 6, "want every form represented");
    let bin = format!("{path}.bin");
    let good = std::fs::read(&bin).unwrap();

    let mut cuts: Vec<usize> = Vec::new();
    for e in states {
        let off = e.get("sec_offset").and_then(|x| x.as_usize()).expect("sealed section");
        let len = e.get("sec_len").and_then(|x| x.as_usize()).expect("sealed section");
        cuts.push(off); // exactly at the boundary before this section
        if len > 1 {
            cuts.push(off + len / 2); // torn mid-section
            cuts.push(off + len - 1); // one byte short of complete
        }
    }
    cuts.sort_unstable();
    cuts.dedup();
    for &cut in &cuts {
        assert!(cut < good.len());
        std::fs::write(&bin, &good[..cut]).unwrap();
        let mut fresh = CompressedAdamW::new(hp, policy);
        let err = load_opt_state(&path, &mut fresh).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "cut at {cut}");
        let msg = err.to_string();
        assert!(
            msg.contains("section"),
            "cut at {cut}: error must name a section, got: {msg}"
        );
    }

    // Restore the intact blob: the checkpoint loads and resumes.
    std::fs::write(&bin, &good).unwrap();
    let mut fresh = CompressedAdamW::new(hp, policy);
    load_opt_state(&path, &mut fresh).unwrap();
    assert_eq!(fresh.t(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
