//! Acceptance suite for the executable offload pipeline.
//!
//! 1. **Bit-identity**: offloaded `adamw4` and `adamw32` steps equal
//!    their in-memory engine counterparts bit-for-bit at thread counts
//!    1/2/7 and prefetch depths 1/2/4 (plus stochastic-rounding, 8-bit
//!    and factored spot checks) — the pipeline's staging, dependency
//!    discipline and shared kernels may never change a single byte.
//! 2. **Speedup**: on the PCIe profile, the measured 4-bit-vs-32-bit
//!    virtual-time speedup is > 1 and within 15% of the analytic
//!    `speedup_vs_fp32` — the paper's Tab. 4 reduced-communication
//!    claim, now exercised by actually moving the bytes.
//! 3. **Oracle convergence** (property): as the shard count grows, the
//!    pipeline's virtual step time converges to `simulate_step`'s
//!    analytic estimate for the 32/8/4-bit presets (zero-latency link,
//!    so the oracle's once-per-step latency convention and the
//!    pipeline's per-transfer one coincide).

use lowbit_opt::memory::StatePreset;
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::offload::{simulate_step, speedup_vs_fp32, LinkModel, OffloadConfig};
use lowbit_opt::optim::adamw::AdamW;
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::rng::Pcg64;

const SHARD_ELEMS: usize = 512;
const STEPS: usize = 4;
const THREADS: [usize; 3] = [1, 2, 7];
const DEPTHS: [usize; 3] = [1, 2, 4];

fn mixed_params() -> Vec<Param> {
    let mut rng = Pcg64::seeded(7);
    vec![
        // 2-D, multi-shard under rank-1 row alignment.
        Param::new("w2d", ParamKind::Weight, Tensor::randn(&[40, 96], 0.5, &mut rng)),
        // 1-D, multi-shard under B128 alignment.
        Param::new("w1d", ParamKind::Weight, Tensor::randn(&[6000], 0.5, &mut rng)),
        // 2-D, two shards.
        Param::new("w2d_b", ParamKind::Weight, Tensor::randn(&[24, 32], 0.5, &mut rng)),
        // Tiny tensor, coalesced with whatever shard has room.
        Param::new("bias", ParamKind::Bias, Tensor::randn(&[10], 0.5, &mut rng)),
    ]
}

fn step_grads(params: &[Param], s: usize) -> Vec<Tensor> {
    let mut grng = Pcg64::seeded(1000 + s as u64);
    params
        .iter()
        .map(|p| Tensor::randn(&p.tensor.shape, 0.1, &mut grng))
        .collect()
}

/// The link used by the identity matrix — timing is irrelevant there,
/// only the execution path matters.
fn any_link() -> LinkModel {
    LinkModel::pcie_offload(1e-3)
}

#[derive(PartialEq, Debug)]
struct RunOut {
    weights: Vec<Vec<f32>>,
    moments: Vec<(Vec<f32>, Vec<f32>)>,
    state_bytes: usize,
}

fn run_compressed(policy: QuantPolicy, threads: usize, offload: Option<usize>) -> RunOut {
    let hp = Hyper::default();
    let mut opt = CompressedAdamW::new(hp, policy)
        .with_threads(threads)
        .with_shard_elems(SHARD_ELEMS);
    if let Some(depth) = offload {
        opt = opt.offloaded(OffloadConfig::new(any_link(), depth));
    }
    let mut params = mixed_params();
    for s in 0..STEPS {
        let grads = step_grads(&params, s);
        opt.step(&mut params, &grads, 1e-2);
    }
    RunOut {
        weights: params.iter().map(|p| p.tensor.data.clone()).collect(),
        moments: (0..params.len())
            .map(|i| {
                let (m, v) = opt.moments(i).expect("moments");
                (m.data, v.data)
            })
            .collect(),
        state_bytes: opt.state_bytes(),
    }
}

fn quantize_everything(mut policy: QuantPolicy) -> QuantPolicy {
    policy.min_quant_size = 0;
    policy
}

#[test]
fn offloaded_adamw4_is_bit_identical_at_every_thread_count_and_depth() {
    let baseline = run_compressed(quantize_everything(QuantPolicy::bit4()), 1, None);
    for &t in &THREADS {
        for &d in &DEPTHS {
            let out = run_compressed(quantize_everything(QuantPolicy::bit4()), t, Some(d));
            assert_eq!(
                baseline, out,
                "offloaded adamw4 diverged at threads={t} depth={d}"
            );
        }
    }
}

#[test]
fn offloaded_deep_prefetch_depth_parks_and_stays_bit_identical() {
    // Depth 8 keeps up to eight transfers in flight ahead of compute, so
    // compute entries routinely outrun their staged inputs and fall
    // through the dependency wait's spin and yield windows into the
    // parked condvar path (regression test for the parked backoff —
    // results may not move by a bit, and the run may not hang).
    let policy = || quantize_everything(QuantPolicy::bit4().stochastic());
    let baseline = run_compressed(policy(), 1, None);
    for &t in &THREADS {
        let out = run_compressed(policy(), t, Some(8));
        assert_eq!(
            baseline, out,
            "deep-depth offloaded adamw4 diverged at threads={t}"
        );
    }
}

#[test]
fn offloaded_stochastic_rounding_matches_in_memory_streams() {
    // SR consumes the per-task RNG streams; the offloaded schedule must
    // draw the identical sequence.
    let policy = || quantize_everything(QuantPolicy::bit4().stochastic());
    let baseline = run_compressed(policy(), 1, None);
    for &t in &THREADS {
        for &d in &DEPTHS {
            let out = run_compressed(policy(), t, Some(d));
            assert_eq!(baseline, out, "SR diverged at threads={t} depth={d}");
        }
    }
}

#[test]
fn offloaded_bit8_and_factored_match_in_memory() {
    for (label, policy) in [
        ("adamw8", quantize_everything(QuantPolicy::bit8())),
        ("factor4", quantize_everything(QuantPolicy::bit4().factored())),
    ] {
        let baseline = run_compressed(policy, 1, None);
        let out = run_compressed(policy, 2, Some(2));
        assert_eq!(baseline, out, "{label} offloaded diverged");
    }
}

#[test]
fn offloaded_adamw32_matches_sequential_reference_bitwise() {
    let hp = Hyper::default();
    let run = |mk: &dyn Fn() -> AdamW| -> Vec<Vec<f32>> {
        let mut opt = mk();
        let mut params = mixed_params();
        for s in 0..STEPS {
            let grads = step_grads(&params, s);
            opt.step(&mut params, &grads, 1e-2);
        }
        params.into_iter().map(|p| p.tensor.data).collect()
    };
    let reference = run(&|| AdamW::sequential(hp));
    for &t in &THREADS {
        for &d in &DEPTHS {
            let out = run(&|| {
                AdamW::new(hp)
                    .with_threads(t)
                    .with_shard_elems(SHARD_ELEMS)
                    .offloaded(OffloadConfig::new(any_link(), d))
            });
            assert_eq!(
                reference, out,
                "offloaded adamw32 diverged at threads={t} depth={d}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Virtual-time acceptance vs the analytic oracle.
// ---------------------------------------------------------------------

/// A transformer config whose tensors are large enough that per-transfer
/// latency is a small term against the byte traffic.
fn offload_cfg() -> TransformerConfig {
    TransformerConfig {
        vocab: 4096,
        d_model: 768,
        n_heads: 12,
        d_ff: 3072,
        n_layers: 2,
        max_seq: 128,
    }
}

/// Run `steps` offloaded steps of a preset over `cfg`'s real parameter
/// set and return the mean virtual step time.
fn pipeline_step_seconds(
    cfg: &TransformerConfig,
    preset: StatePreset,
    link: LinkModel,
    depth: usize,
    shard_elems: usize,
    steps: usize,
) -> f64 {
    let hp = Hyper::default();
    let mut rng = Pcg64::seeded(99);
    let mut params = cfg.init_params(&mut rng);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let ocfg = OffloadConfig::new(link, depth);
    match preset {
        StatePreset::AdamW32 => {
            let mut opt = AdamW::new(hp).with_shard_elems(shard_elems).offloaded(ocfg);
            for _ in 0..steps {
                opt.step(&mut params, &grads, 1e-3);
            }
            opt.offload_report().expect("offloaded").step_seconds()
        }
        StatePreset::AdamW8 => {
            let mut opt = CompressedAdamW::new(hp, QuantPolicy::bit8())
                .with_shard_elems(shard_elems)
                .offloaded(ocfg);
            for _ in 0..steps {
                opt.step(&mut params, &grads, 1e-3);
            }
            opt.offload_report().expect("offloaded").step_seconds()
        }
        StatePreset::AdamW4 => {
            let mut opt = CompressedAdamW::new(hp, QuantPolicy::bit4())
                .with_shard_elems(shard_elems)
                .offloaded(ocfg);
            for _ in 0..steps {
                opt.step(&mut params, &grads, 1e-3);
            }
            opt.offload_report().expect("offloaded").step_seconds()
        }
        _ => unreachable!("presets under test"),
    }
}

#[test]
fn pcie_speedup_is_real_and_near_the_analytic_model() {
    // The acceptance criterion: measured 4-bit-vs-32-bit virtual-time
    // speedup on the PCIe profile > 1 and within 15% of the analytic
    // `speedup_vs_fp32`.
    let cfg = offload_cfg();
    let compute = 4.0 * cfg.n_params() as f64 / 6.9e9;
    let link = LinkModel::pcie_offload(compute);
    // Large shards keep the per-transfer latency term (which the
    // analytic oracle charges only once) a small correction.
    let shard = 1 << 20;
    let t32 = pipeline_step_seconds(&cfg, StatePreset::AdamW32, link, 2, shard, 2);
    let t4 = pipeline_step_seconds(&cfg, StatePreset::AdamW4, link, 2, shard, 2);
    let measured = t32 / t4;
    let analytic = speedup_vs_fp32(&cfg, StatePreset::AdamW4, &link);
    assert!(
        measured > 1.0,
        "4-bit offload must beat 32-bit: measured {measured:.3}"
    );
    let rel = (measured / analytic - 1.0).abs();
    assert!(
        rel < 0.15,
        "measured speedup {measured:.3} vs analytic {analytic:.3} ({:.1}% apart)",
        100.0 * rel
    );
}

#[test]
fn pipeline_virtual_time_converges_to_the_analytic_oracle() {
    // Property: for the 32/8/4-bit presets, the pipeline's virtual step
    // total approaches the analytic estimate as the shard count grows
    // (edge effects vanish). Zero-latency link so both accountings
    // charge identical per-byte costs.
    let cfg = TransformerConfig {
        vocab: 2048,
        d_model: 256,
        n_heads: 8,
        d_ff: 1024,
        n_layers: 2,
        max_seq: 64,
    };
    let compute = 4.0 * cfg.n_params() as f64 / 6.9e9;
    let link = LinkModel {
        bandwidth: 25e9,
        latency: 0.0,
        compute_per_step: compute,
        overlap: 0.5,
    };
    // Coarse → fine sharding: shard counts grow ~16x across the sweep.
    let shard_sizes = [1usize << 18, 1 << 16, 1 << 14];
    for preset in [StatePreset::AdamW32, StatePreset::AdamW8, StatePreset::AdamW4] {
        let analytic = simulate_step(&cfg, preset, &link).step_seconds;
        let errs: Vec<f64> = shard_sizes
            .iter()
            .map(|&se| {
                let t = pipeline_step_seconds(&cfg, preset, link, 2, se, 1);
                (t - analytic).abs() / analytic
            })
            .collect();
        assert!(
            errs[2] < 0.05,
            "{}: finest-shard error {:.3} vs analytic {analytic:.6}s (errs {errs:?})",
            preset.label(),
            errs[2]
        );
        assert!(
            errs[2] <= errs[0] + 1e-9,
            "{}: error must not grow with shard count (errs {errs:?})",
            preset.label()
        );
    }
}

#[test]
fn depth_one_serializes_and_deeper_pipelines_overlap() {
    let cfg = offload_cfg();
    let compute = 4.0 * cfg.n_params() as f64 / 6.9e9;
    let link = LinkModel::pcie_offload(compute);
    let serial = pipeline_step_seconds(&cfg, StatePreset::AdamW32, link, 1, 1 << 20, 1);
    let piped = pipeline_step_seconds(&cfg, StatePreset::AdamW32, link, 2, 1 << 20, 1);
    assert!(
        serial > piped,
        "depth 1 must be slower than a pipelined depth: {serial:.5}s vs {piped:.5}s"
    );
    // Depth 1 is exactly compute + all communication.
    let hp = Hyper::default();
    let mut rng = Pcg64::seeded(99);
    let mut params = cfg.init_params(&mut rng);
    let grads: Vec<Tensor> = cfg
        .param_specs()
        .iter()
        .map(|(_, _, s)| Tensor::randn(s, 0.01, &mut rng))
        .collect();
    let mut opt = AdamW::new(hp)
        .with_shard_elems(1 << 20)
        .offloaded(OffloadConfig::new(link, 1));
    opt.step(&mut params, &grads, 1e-3);
    let rep = opt.offload_report().expect("offloaded");
    assert_eq!(rep.steps, 1);
    assert!(rep.bytes_down > 0 && rep.bytes_up > 0);
    assert_eq!(rep.hidden_seconds, 0.0, "depth 1 never overlaps");
    assert!((rep.virtual_seconds - (compute + rep.comm_seconds)).abs() < 1e-12);
}
