//! End-to-end pin of the kernel-tier dispatch: `LOWBIT_KERNEL_TIER` is
//! read once per process by `active_tier`, so this test binary — which
//! sets the variable before anything touches the quant layer — locks
//! both the forced-scalar override and the read-once caching. The pure
//! resolution rule is covered alongside, plus its two hard-error arms.
//!
//! Kept separate from `quant_tiers.rs` on purpose: that binary resolves
//! the tier naturally (auto), this one forces `scalar`; a process can
//! only ever observe one resolution.

use lowbit_opt::quant::{active_tier, resolve_tier, KernelTier};

#[test]
fn forced_scalar_tier_is_resolved_and_cached() {
    // Runs before any kernel dispatch in this process: the integration
    // binary only touches `active_tier` here.
    std::env::set_var("LOWBIT_KERNEL_TIER", "scalar");
    assert_eq!(active_tier(), KernelTier::Scalar);
    // Read-once semantics: later changes to the environment must not
    // re-resolve the tier (no env syscall on the kernel hot path).
    std::env::set_var("LOWBIT_KERNEL_TIER", "auto");
    assert_eq!(active_tier(), KernelTier::Scalar);
    std::env::remove_var("LOWBIT_KERNEL_TIER");
    assert_eq!(active_tier(), KernelTier::Scalar);
}

#[test]
fn resolve_tier_pure_rules() {
    assert_eq!(resolve_tier(None, false), KernelTier::Scalar);
    assert_eq!(resolve_tier(None, true), KernelTier::Avx2);
    assert_eq!(resolve_tier(Some(""), true), KernelTier::Avx2);
    assert_eq!(resolve_tier(Some("auto"), false), KernelTier::Scalar);
    assert_eq!(resolve_tier(Some("AUTO"), true), KernelTier::Avx2);
    assert_eq!(resolve_tier(Some(" scalar "), true), KernelTier::Scalar);
    assert_eq!(resolve_tier(Some("Scalar"), false), KernelTier::Scalar);
    assert_eq!(resolve_tier(Some("avx2"), true), KernelTier::Avx2);
    assert_eq!(resolve_tier(Some("AVX2"), true), KernelTier::Avx2);
}

#[test]
fn forcing_avx2_without_cpu_support_is_a_hard_error() {
    let r = std::panic::catch_unwind(|| resolve_tier(Some("avx2"), false));
    assert!(r.is_err(), "forcing avx2 on a non-AVX2 CPU must panic");
}

#[test]
fn unknown_tier_value_is_a_hard_error() {
    let r = std::panic::catch_unwind(|| resolve_tier(Some("sse9"), true));
    assert!(r.is_err(), "unknown tier values must panic, not fall back");
}
