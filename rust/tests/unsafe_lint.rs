//! Tier-1 lock on the unsafe-boundary lint (`rust/src/bin/lint.rs`).
//!
//! Two jobs: (1) the shipped tree must be clean — this is the test that
//! makes the lint a merge gate; (2) the lint's own behavior is locked
//! against seeded violation trees, so a regression in the scanner (a
//! string-masking bug, a loosened adjacency rule) fails here rather
//! than silently letting real violations through.
//!
//! The lint source is included directly (same code as the `lint`
//! binary), so the rules under test are exactly the rules CI runs.

#[path = "../src/bin/lint.rs"]
#[allow(dead_code)]
mod lint;

use lint::{run_lint, Kind, Violation, ALLOWLIST, PARENT_EXEMPT};
use std::fs;
use std::path::{Path, PathBuf};

fn shipped_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
}

/// Compact (file, line, kind) view for assertions.
fn found(violations: &[Violation]) -> Vec<(String, usize, Kind)> {
    violations
        .iter()
        .map(|v| (v.file.clone(), v.line, v.kind))
        .collect()
}

/// A scratch source tree under the system temp dir, removed on drop.
struct TempTree {
    root: PathBuf,
}

impl TempTree {
    fn new(name: &str) -> TempTree {
        let root = std::env::temp_dir().join(format!("lowbit_lint_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).expect("create temp tree");
        TempTree { root }
    }

    fn write(&self, rel: &str, contents: &str) -> &TempTree {
        let path = self.root.join(rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).expect("create module dir");
        }
        fs::write(path, contents).expect("write seeded file");
        self
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

// ---------------------------------------------------------------------
// The merge gate: the shipped tree passes every rule.

#[test]
fn shipped_tree_is_clean() {
    let violations = run_lint(&shipped_root());
    assert!(
        violations.is_empty(),
        "unsafe-boundary lint found violations in the shipped tree:\n{}",
        violations
            .iter()
            .map(|v| format!("  {}:{}: [{:?}] {}", v.file, v.line, v.kind, v.msg))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn allowlist_and_exemptions_name_real_files() {
    let root = shipped_root();
    for rel in ALLOWLIST.iter().chain(PARENT_EXEMPT.iter()) {
        assert!(
            root.join(rel).is_file(),
            "lint allowlist names a file that no longer exists: {rel}"
        );
    }
}

// ---------------------------------------------------------------------
// Seeded violations: each rule fires where it should and only there.

#[test]
fn undocumented_unsafe_in_allowlisted_file_is_flagged() {
    let t = TempTree::new("undoc");
    t.write(
        "engine/shared.rs",
        "pub fn read(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    );
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![("engine/shared.rs".to_string(), 2, Kind::UndocumentedUnsafe)]
    );
}

#[test]
fn documented_unsafe_in_allowlisted_file_is_clean() {
    let t = TempTree::new("doc");
    t.write(
        "engine/shared.rs",
        "pub fn read(p: *const u8) -> u8 {\n    \
         // SAFETY: caller keeps p valid.\n    unsafe { *p }\n}\n",
    );
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
}

#[test]
fn unsafe_outside_allowlist_is_flagged_along_with_missing_stamp() {
    let t = TempTree::new("outside");
    t.write(
        "quant/extra.rs",
        "pub fn read(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    );
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![
            ("quant/extra.rs".to_string(), 1, Kind::MissingForbidStamp),
            ("quant/extra.rs".to_string(), 2, Kind::UnsafeOutsideAllowlist),
        ]
    );
}

#[test]
fn masked_tokens_never_trip_the_scanner() {
    let t = TempTree::new("masked");
    t.write(
        "util/masked.rs",
        concat!(
            "#![forbid(unsafe_code)]\n",
            "//! unsafe in docs is fine; so is `static mut` prose.\n",
            "/* block comment: unsafe { transmute } static mut */\n",
            "pub const A: &str = \"unsafe { boom }\";\n",
            "pub const B: &str = r#\"static mut X: transmute\"#;\n",
            "pub const C: &[u8] = b\"unsafe bytes\";\n",
            "pub const D: char = 'u';\n",
            "pub const E: u8 = b'x';\n",
            "pub fn lifetimes<'a>(x: &'a str) -> &'a str { x }\n",
            "pub fn unsafe_code_adjacent_ident() {}\n",
        ),
    );
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
}

#[test]
fn static_mut_and_transmute_outside_allowlist_are_flagged() {
    let t = TempTree::new("staticmut");
    t.write(
        "util/bad.rs",
        concat!(
            "#![forbid(unsafe_code)]\n",
            "static mut COUNTER: u32 = 0;\n",
            "pub fn f(x: u32) -> u32 { core::mem::transmute(x) }\n",
        ),
    );
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![
            ("util/bad.rs".to_string(), 2, Kind::StaticMut),
            ("util/bad.rs".to_string(), 3, Kind::Transmute),
        ]
    );
}

#[test]
fn lib_rs_without_the_unsafe_op_deny_is_flagged() {
    let t = TempTree::new("libdeny");
    t.write("lib.rs", "pub mod util;\n");
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![("lib.rs".to_string(), 1, Kind::MissingLibDeny)]
    );
    t.write(
        "lib.rs",
        "#![deny(unsafe_op_in_unsafe_fn)]\npub mod util;\n",
    );
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
}

#[test]
fn blank_line_breaks_safety_adjacency() {
    let t = TempTree::new("blank");
    t.write(
        "engine/shared.rs",
        "pub fn read(p: *const u8) -> u8 {\n    \
         // SAFETY: stale, no longer adjacent.\n\n    unsafe { *p }\n}\n",
    );
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![("engine/shared.rs".to_string(), 4, Kind::UndocumentedUnsafe)]
    );
}

#[test]
fn attribute_lines_do_not_break_safety_adjacency() {
    let t = TempTree::new("attrs");
    t.write(
        "engine/pool.rs",
        concat!(
            "/// Reads a byte.\n",
            "///\n",
            "/// # Safety\n",
            "/// `p` must be valid for reads.\n",
            "#[inline]\n",
            "pub unsafe fn read(p: *const u8) -> u8 {\n",
            "    // SAFETY: contract forwarded to the caller above.\n",
            "    unsafe { *p }\n",
            "}\n",
        ),
    );
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
}

#[test]
fn missing_forbid_stamp_is_flagged_and_the_stamp_fixes_it() {
    let t = TempTree::new("stamp");
    t.write("exp/new_tool.rs", "pub fn f() -> u32 {\n    7\n}\n");
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![("exp/new_tool.rs".to_string(), 1, Kind::MissingForbidStamp)]
    );
    t.write(
        "exp/new_tool.rs",
        "#![forbid(unsafe_code)]\npub fn f() -> u32 {\n    7\n}\n",
    );
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
}

#[test]
fn parent_exempt_modules_skip_the_stamp_but_not_the_unsafe_ban() {
    let t = TempTree::new("parent");
    // No stamp required on a parent-exempt module root...
    t.write("offload/mod.rs", "pub mod tier;\n");
    let got = run_lint(&t.root);
    assert!(got.is_empty(), "{:?}", found(&got));
    // ...but unsafe inside it is still banned.
    t.write(
        "offload/mod.rs",
        "pub mod tier;\npub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    );
    assert_eq!(
        found(&run_lint(&t.root)),
        vec![("offload/mod.rs".to_string(), 3, Kind::UnsafeOutsideAllowlist)]
    );
}
