//! Property tests (via `util/propcheck`) for the shard planner's
//! invariants. The whole engine-safety story rests on these: every
//! `unsafe` range access in the executors cites a plan invariant, so the
//! invariants get hammered here across arbitrary tensor-shape mixes,
//! state-layout mixes and shard sizes:
//!
//! * pieces of each tensor are disjoint, in order, and cover every
//!   element exactly once;
//! * piece boundaries respect the tensor's alignment (blocks, rows,
//!   nibble bytes);
//! * stat slots exist exactly for Global-m / Global-or-Factored-v
//!   pieces, are never shared, and carry the declared lengths;
//! * the plan is a pure function of (metas, shard_elems) — thread count
//!   never enters, and rebuilding reproduces it exactly;
//! * splitting actually splits (big tensors get ≥ 2 pieces when their
//!   alignment allows) and coalescing keeps small tensors whole.

use lowbit_opt::engine::plan::{alignment, build_plan, Plan, StateLayout, TensorMeta};
use lowbit_opt::util::propcheck::{check, Gen};

fn gen_shape(g: &mut Gen) -> Vec<usize> {
    match g.rng.below(10) {
        // Occasional empty tensor: the planner must skip it cleanly.
        0 => vec![0],
        1..=4 => vec![1 + g.rng.below(6000)],
        5..=8 => vec![1 + g.rng.below(48), 1 + g.rng.below(96)],
        _ => vec![1 + g.rng.below(12), 1 + g.rng.below(8), 1 + g.rng.below(10)],
    }
}

fn gen_meta(g: &mut Gen) -> TensorMeta {
    let shape = gen_shape(g);
    let numel: usize = shape.iter().product();
    let blocks = [64usize, 128, 2048];
    let m = match g.rng.below(3) {
        0 => StateLayout::F32,
        1 => StateLayout::Block(*g.choose(&blocks)),
        _ => StateLayout::Global,
    };
    let v = match g.rng.below(4) {
        0 => StateLayout::F32,
        1 => StateLayout::Block(*g.choose(&blocks)),
        2 => StateLayout::Global,
        // Factorization needs >= 2 dims; 1-D falls back to Block.
        _ if shape.len() >= 2 => StateLayout::Factored,
        _ => StateLayout::Block(128),
    };
    let axis_sum: usize = shape.iter().sum();
    let m_stat_len = match m {
        StateLayout::Global => {
            if shape.len() >= 2 {
                axis_sum
            } else {
                1
            }
        }
        _ => 0,
    };
    let v_stat_len = match v {
        StateLayout::Global => {
            if shape.len() >= 2 {
                axis_sum
            } else {
                1
            }
        }
        StateLayout::Factored => shape[0] + numel / shape[0],
        _ => 0,
    };
    TensorMeta {
        numel,
        shape,
        m,
        v,
        m_stat_len,
        v_stat_len,
    }
}

fn gen_metas(g: &mut Gen) -> Vec<TensorMeta> {
    let n = 1 + g.rng.below(8);
    (0..n).map(|_| gen_meta(g)).collect()
}

fn gen_shard_elems(g: &mut Gen) -> usize {
    *g.choose(&[2usize, 64, 512, 4096, 1 << 16])
}

/// Pieces of tensor `ti` in plan traversal order.
fn pieces_of(plan: &Plan, ti: usize) -> Vec<(usize, usize, Option<usize>, Option<usize>)> {
    let mut out = Vec::new();
    for task in &plan.tasks {
        for p in task.pieces.iter().filter(|p| p.tensor == ti) {
            out.push((p.lo, p.hi, p.m_slot, p.v_slot));
        }
    }
    out
}

#[test]
fn prop_pieces_cover_each_tensor_disjointly_and_aligned() {
    check("plan coverage + alignment", 300, |g| {
        let metas = gen_metas(g);
        let shard = gen_shard_elems(g);
        let plan = build_plan(&metas, shard);
        let want_total: usize = metas.iter().map(|m| m.numel).sum();
        if plan.total_elems != want_total {
            return Err(format!(
                "total_elems {} != sum of numels {want_total}",
                plan.total_elems
            ));
        }
        for (ti, meta) in metas.iter().enumerate() {
            let align = alignment(meta);
            let mut cursor = 0usize;
            for (lo, hi, _, _) in pieces_of(&plan, ti) {
                if lo != cursor {
                    return Err(format!("tensor {ti}: gap/overlap at {lo} (cursor {cursor})"));
                }
                if hi <= lo || hi > meta.numel {
                    return Err(format!("tensor {ti}: bad piece [{lo}, {hi})"));
                }
                if lo % align != 0 {
                    return Err(format!("tensor {ti}: lo {lo} not {align}-aligned"));
                }
                if hi != meta.numel && hi % align != 0 {
                    return Err(format!("tensor {ti}: hi {hi} not {align}-aligned"));
                }
                cursor = hi;
            }
            if cursor != meta.numel {
                return Err(format!(
                    "tensor {ti}: covered only {cursor} of {} elements",
                    meta.numel
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_is_pure_in_its_inputs() {
    check("plan purity", 200, |g| {
        let metas = gen_metas(g);
        let shard = gen_shard_elems(g);
        let a = build_plan(&metas, shard);
        let b = build_plan(&metas, shard);
        if a.tasks.len() != b.tasks.len() || a.slot_lens != b.slot_lens {
            return Err("rebuild changed task/slot structure".into());
        }
        for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
            if x.pieces.len() != y.pieces.len() {
                return Err("rebuild changed piece count".into());
            }
            for (p, q) in x.pieces.iter().zip(y.pieces.iter()) {
                if (p.tensor, p.lo, p.hi, p.m_slot, p.v_slot)
                    != (q.tensor, q.lo, q.hi, q.m_slot, q.v_slot)
                {
                    return Err(format!(
                        "rebuild changed a piece of tensor {} at {}",
                        p.tensor, p.lo
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stat_slots_unique_and_correctly_sized() {
    check("stat slots", 300, |g| {
        let metas = gen_metas(g);
        let shard = gen_shard_elems(g);
        let plan = build_plan(&metas, shard);
        let mut seen = std::collections::BTreeSet::new();
        for task in &plan.tasks {
            for p in &task.pieces {
                let meta = &metas[p.tensor];
                match (meta.m == StateLayout::Global, p.m_slot) {
                    (true, None) => return Err(format!("tensor {}: global m, no slot", p.tensor)),
                    (false, Some(_)) => {
                        return Err(format!("tensor {}: non-global m got a slot", p.tensor))
                    }
                    (true, Some(s)) => {
                        if !seen.insert(s) {
                            return Err(format!("m slot {s} reused"));
                        }
                        if plan.slot_lens[s] != meta.m_stat_len {
                            return Err(format!(
                                "m slot {s} len {} != declared {}",
                                plan.slot_lens[s], meta.m_stat_len
                            ));
                        }
                    }
                    (false, None) => {}
                }
                let v_wants_slot =
                    matches!(meta.v, StateLayout::Global | StateLayout::Factored);
                match (v_wants_slot, p.v_slot) {
                    (true, None) => return Err(format!("tensor {}: stat v, no slot", p.tensor)),
                    (false, Some(_)) => {
                        return Err(format!("tensor {}: plain v got a slot", p.tensor))
                    }
                    (true, Some(s)) => {
                        if !seen.insert(s) {
                            return Err(format!("v slot {s} reused"));
                        }
                        if plan.slot_lens[s] != meta.v_stat_len {
                            return Err(format!(
                                "v slot {s} len {} != declared {}",
                                plan.slot_lens[s], meta.v_stat_len
                            ));
                        }
                    }
                    (false, None) => {}
                }
            }
        }
        if seen.len() != plan.slot_lens.len() {
            return Err(format!(
                "{} slots allocated but {} referenced",
                plan.slot_lens.len(),
                seen.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_split_and_coalesce_behaviour() {
    check("split/coalesce", 300, |g| {
        let metas = gen_metas(g);
        let shard = gen_shard_elems(g);
        let plan = build_plan(&metas, shard);
        let target = shard.max(2);
        for (ti, meta) in metas.iter().enumerate() {
            let pieces = pieces_of(&plan, ti);
            let align = alignment(meta);
            if meta.numel > target && align < meta.numel {
                if pieces.len() < 2 {
                    return Err(format!(
                        "tensor {ti} ({} elems, target {target}, align {align}) \
                         was not split: {} piece(s)",
                        meta.numel,
                        pieces.len()
                    ));
                }
            } else if meta.numel > 0 && pieces.len() != 1 {
                // Small (coalesced) and unsplittable tensors stay whole.
                return Err(format!(
                    "tensor {ti} ({} elems) expected 1 piece, got {}",
                    meta.numel,
                    pieces.len()
                ));
            }
            if meta.numel == 0 && !pieces.is_empty() {
                return Err(format!("empty tensor {ti} got pieces"));
            }
        }
        for (i, task) in plan.tasks.iter().enumerate() {
            if task.pieces.is_empty() {
                return Err(format!("task {i} is empty"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_independent_of_thread_count_by_construction() {
    // `build_plan` has no thread parameter at all — this test documents
    // the API-level guarantee and checks the plan shape only depends on
    // shard_elems by comparing two different engines' worth of inputs.
    check("plan thread-blindness", 100, |g| {
        let metas = gen_metas(g);
        let shard = gen_shard_elems(g);
        // Simulate "different thread counts" by just building repeatedly
        // interleaved with unrelated allocations; the plan must be
        // byte-for-byte stable.
        let a = build_plan(&metas, shard);
        let _noise: Vec<u8> = vec![0; 1 + g.rng.below(4096)];
        let b = build_plan(&metas, shard);
        if a.tasks.len() != b.tasks.len() {
            return Err("plan not stable across rebuilds".into());
        }
        Ok(())
    });
}
