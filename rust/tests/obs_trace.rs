#![forbid(unsafe_code)]
// The whole suite needs recorded spans; without the feature the file
// compiles to an empty (trivially green) test target.
#![cfg(feature = "trace")]
//! Trace determinism + export-validity suite (`--features trace`).
//!
//! The engine's bit-identical-at-any-thread-count contract extends to its
//! telemetry: with identical seeds, the *schedule-independent* part of a
//! trace — the coordinator's phase sequence and the multiset of worker
//! `(phase, task)` spans — must be identical across runs, thread counts
//! and scheduler modes. Only timestamps and the worker↔task assignment
//! may differ. The fingerprint here is recovered purely through the
//! public chrome://tracing export, so it also pins the export format.

use lowbit_opt::engine::SchedMode;
use lowbit_opt::obs::trace::PHASE_NAMES;
use lowbit_opt::offload::{LinkModel, OffloadConfig};
use lowbit_opt::optim::lowbit::{CompressedAdamW, QuantPolicy};
use lowbit_opt::optim::{Hyper, Optimizer, Param, ParamKind};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn model(seed: u64) -> (Vec<Param>, Vec<Tensor>) {
    let shapes: [&[usize]; 3] = [&[64, 32], &[48], &[32, 16]];
    let mut rng = Pcg64::seeded(seed);
    let params = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Param::new(&format!("p{i}"), ParamKind::Weight, Tensor::randn(s, 0.1, &mut rng))
        })
        .collect();
    let grads = shapes.iter().map(|s| Tensor::randn(s, 0.01, &mut rng)).collect();
    (params, grads)
}

fn policy() -> QuantPolicy {
    let mut p = QuantPolicy::bit4();
    p.min_quant_size = 0; // quantize even the tiny test tensors
    p
}

/// Run `steps` compressed steps and export the trace (the rings hold a
/// rolling window; at this size nothing wraps, so it covers every step).
fn traced_run(threads: usize, sched: SchedMode, steps: usize) -> Json {
    let mut opt = CompressedAdamW::new(Hyper::default(), policy())
        .with_threads(threads)
        .with_shard_elems(256)
        .with_sched(sched);
    let (mut params, grads) = model(9);
    for _ in 0..steps {
        opt.step(&mut params, &grads, 1e-3);
    }
    opt.export_trace().expect("trace feature is on")
}

/// The schedule-independent fingerprint, recovered from the export:
/// coordinator (tid 0) phase names in recorded order + sorted multiset
/// of worker `(name, task)` pairs. Timestamps excluded by construction.
fn fingerprint(doc: &Json) -> (Vec<String>, Vec<(String, u64)>) {
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut coord = Vec::new();
    let mut tasks = Vec::new();
    for ev in events {
        let name = ev.get("name").unwrap().as_str().unwrap().to_string();
        let tid = ev.get("tid").unwrap().as_f64().unwrap() as u64;
        if tid == 0 {
            coord.push(name);
        } else {
            let task = ev
                .get("args")
                .and_then(|a| a.get("task"))
                .and_then(Json::as_f64)
                .expect("worker spans carry a task arg") as u64;
            tasks.push((name, task));
        }
    }
    tasks.sort();
    (coord, tasks)
}

#[test]
fn identical_seeds_give_identical_fingerprints_across_runs() {
    let a = fingerprint(&traced_run(2, SchedMode::Sticky, 3));
    let b = fingerprint(&traced_run(2, SchedMode::Sticky, 3));
    assert!(!a.0.is_empty() && !a.1.is_empty(), "trace should hold spans");
    assert_eq!(a, b, "same seed + settings must reproduce the trace exactly");
}

#[test]
fn fingerprint_is_invariant_across_threads_and_sched_modes() {
    let reference = fingerprint(&traced_run(1, SchedMode::Queue, 3));
    for (threads, sched) in [
        (2, SchedMode::Queue),
        (4, SchedMode::Queue),
        (2, SchedMode::Sticky),
        (7, SchedMode::Sticky),
    ] {
        let f = fingerprint(&traced_run(threads, sched, 3));
        assert_eq!(
            f,
            reference,
            "schedule-independent trace metadata diverged at t{threads} {sched:?}"
        );
    }
}

/// Validate one export's event shape; returns (coordinator names,
/// worker names) for phase-coverage assertions.
fn validate_export(doc: &Json) -> (Vec<String>, Vec<String>) {
    // Round-trip: the serialized document must parse back.
    let back = Json::parse(&doc.to_string()).expect("export must be valid JSON");
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut coord_names = Vec::new();
    let mut worker_names = Vec::new();
    for ev in events {
        let name = ev.get("name").unwrap().as_str().unwrap();
        assert!(PHASE_NAMES.contains(&name), "unknown phase name '{name}'");
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        for key in ["ts", "dur"] {
            let x = ev.get(key).unwrap().as_f64().unwrap();
            assert!(x.is_finite() && x >= 0.0, "{key}={x}");
        }
        if ev.get("tid").unwrap().as_f64() == Some(0.0) {
            coord_names.push(name.to_string());
        } else {
            worker_names.push(name.to_string());
        }
    }
    (coord_names, worker_names)
}

#[test]
fn chrome_export_validates_and_names_engine_phases() {
    // bit4 exercises A → reduce → C (rank-1 globals) → commit; phase F
    // runs only for factored second moments, covered separately below.
    let doc = traced_run(4, SchedMode::Sticky, 2);
    let (coord_names, worker_names) = validate_export(&doc);
    for want in ["engine.A", "engine.reduce", "engine.C", "engine.commit"] {
        assert!(coord_names.iter().any(|n| n == want), "coordinator missing '{want}'");
    }
    for want in ["engine.A", "engine.C"] {
        assert!(worker_names.iter().any(|n| n == want), "workers missing '{want}'");
    }
}

#[test]
fn factored_policy_names_phase_f() {
    let mut p = QuantPolicy::bit4().factored();
    p.min_quant_size = 0;
    let mut opt = CompressedAdamW::new(Hyper::default(), p)
        .with_threads(2)
        .with_shard_elems(256);
    let (mut params, grads) = model(13);
    for _ in 0..2 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let doc = opt.export_trace().expect("trace feature is on");
    let (coord_names, _) = validate_export(&doc);
    assert!(
        coord_names.iter().any(|n| n == "engine.F"),
        "factored run must record phase F (saw {coord_names:?})"
    );
}

#[test]
fn offloaded_steps_name_every_offload_phase() {
    let link = LinkModel {
        bandwidth: 1e9,
        latency: 0.0,
        compute_per_step: 1.0,
        overlap: 1.0,
    };
    let mut opt = CompressedAdamW::new(Hyper::default(), policy())
        .with_threads(2)
        .with_shard_elems(256)
        .offloaded(OffloadConfig::new(link, 2));
    let (mut params, grads) = model(11);
    for _ in 0..2 {
        opt.step(&mut params, &grads, 1e-3);
    }
    let doc = opt.export_trace().expect("trace feature is on");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let names: Vec<&str> = events
        .iter()
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for want in ["offload.queue", "offload.in", "offload.compute", "offload.out"] {
        assert!(names.contains(&want), "offload trace missing '{want}' (saw {names:?})");
    }
}
