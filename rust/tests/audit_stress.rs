//! Schedule-adversarial stress harness for the engine executors and the
//! aliasing auditor (`--features audit`).
//!
//! The positive tests run unconditionally: deterministic permutations of
//! claim order (seeded yield injection inside task bodies) drive
//! `run_tasks`, `run_tasks_with` and `run_tasks_dep` over disjoint
//! segments, worker-slot scratch, and dependency-chained range reuse —
//! the exact access patterns the executors promise at their `unsafe`
//! sites. With the `audit` feature on, every one of these runs is also a
//! check that the auditor raises **no false alarms** on legal schedules
//! (phase retirement, dependency chains, zero-sized types, empty
//! ranges).
//!
//! The `negative` module (audit builds only) checks the teeth: an
//! overlapping `range_mut` pair aborts naming both call sites, the pool
//! propagates the abort, out-of-bounds ranges abort, and a task scope
//! that outlives its phase barrier aborts.

use lowbit_opt::engine::{Affinity, SchedMode, SharedSlice, StepEngine};
use lowbit_opt::util::rng::Pcg64;

/// Deterministic per-(seed, task) schedule perturbation: a few yields
/// before the task touches shared memory, so different seeds exercise
/// different claim/execution interleavings on the pool.
fn jitter(seed: u64, task: usize) {
    let yields = Pcg64::new(seed, task as u64).below(4);
    for _ in 0..yields {
        std::thread::yield_now();
    }
}

#[test]
fn disjoint_segments_survive_schedule_stress() {
    const SEG: usize = 17;
    const TASKS: usize = 48;
    for &threads in &[2usize, 3, 7] {
        let engine = StepEngine::new().with_threads(threads);
        for seed in 0..6u64 {
            let mut data = vec![0u64; SEG * TASKS];
            let view = SharedSlice::new(&mut data);
            engine.run_tasks::<(), _>(threads, TASKS, |i, _| {
                jitter(seed, i);
                // SAFETY: task i owns segment i — pairwise disjoint.
                let seg = unsafe { view.range_mut(i * SEG, (i + 1) * SEG) };
                for (k, v) in seg.iter_mut().enumerate() {
                    *v = (i * SEG + k) as u64 + 1;
                }
            });
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, k as u64 + 1, "seed {seed}, {threads} threads, elem {k}");
            }
        }
    }
}

#[test]
fn worker_scratch_and_task_ranges_coexist() {
    const SEG: usize = 9;
    const TASKS: usize = 24;
    for &threads in &[2usize, 3] {
        let engine = StepEngine::new().with_threads(threads);
        for seed in 10..30u64 {
            let mut data = vec![0u32; SEG * TASKS];
            let view = SharedSlice::new(&mut data);
            let mut scratch = vec![0u64; threads];
            engine.run_tasks_with(threads, TASKS, &mut scratch, |i, s| {
                jitter(seed, i);
                *s += 1;
                // SAFETY: task i owns segment i — pairwise disjoint.
                let seg = unsafe { view.range_mut(i * SEG, (i + 1) * SEG) };
                for v in seg.iter_mut() {
                    *v = i as u32 + 1;
                }
            });
            assert_eq!(scratch.iter().sum::<u64>(), TASKS as u64, "seed {seed}");
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, (k / SEG) as u32 + 1, "seed {seed}, elem {k}");
            }
        }
    }
}

/// Dependency-chained queue entries may reuse a range: with stride `d`,
/// entry `i` depends on `i - d`, forming `d` independent chains that
/// each hammer one slot (the offload pipeline's slot-reuse discipline).
/// Content checks prove the ordering held; under `--features audit` the
/// run also proves the auditor accepts ancestor-related overlap.
#[test]
fn dependency_chains_may_reuse_ranges() {
    const SLOT: usize = 32;
    const LINKS: usize = 6;
    for &stride in &[1usize, 3] {
        for &threads in &[1usize, 2, 4] {
            let n = LINKS * stride;
            let deps: Vec<Option<usize>> = (0..n)
                .map(|i| if i >= stride { Some(i - stride) } else { None })
                .collect();
            let engine = StepEngine::new().with_threads(threads);
            for seed in 40..46u64 {
                let mut data = vec![0u64; SLOT * stride];
                let view = SharedSlice::new(&mut data);
                let mut scratch = vec![0u8; threads.max(1)];
                engine.run_tasks_dep(threads, &deps, &mut scratch, |i, _| {
                    jitter(seed, i);
                    let chain = i % stride;
                    // SAFETY: the chain's entries are dependency-ordered,
                    // so only one of them can hold this slot at a time.
                    let seg = unsafe { view.range_mut(chain * SLOT, (chain + 1) * SLOT) };
                    for v in seg.iter_mut() {
                        *v += (i + 1) as u64;
                    }
                });
                for c in 0..stride {
                    let want: u64 = (0..LINKS).map(|k| (c + k * stride + 1) as u64).sum();
                    for k in 0..SLOT {
                        assert_eq!(
                            data[c * SLOT + k],
                            want,
                            "stride {stride}, {threads} threads, seed {seed}, chain {c}"
                        );
                    }
                }
            }
        }
    }
}

/// Phase barriers retire intervals: consecutive phases on one engine may
/// assign the same range to *different* tasks without complaint.
#[test]
fn ranges_retire_at_phase_barriers() {
    const SEG: usize = 10;
    let engine = StepEngine::new().with_threads(3);
    let mut data = vec![0u64; 3 * SEG];
    let view = SharedSlice::new(&mut data);
    for round in 0..50usize {
        engine.run_tasks::<(), _>(3, 3, |i, _| {
            // Rotate the task → range assignment every phase: the range
            // task 0 wrote last phase is task 1's now.
            let j = (i + round) % 3;
            // SAFETY: j is a permutation of the task index — disjoint.
            let seg = unsafe { view.range_mut(j * SEG, (j + 1) * SEG) };
            for v in seg.iter_mut() {
                *v += 1;
            }
        });
    }
    assert!(data.iter().all(|&v| v == 50), "{data:?}");
}

/// Zero-sized types and empty ranges carry no bytes, so identical
/// "ranges" from different tasks are not aliasing (regression guard for
/// the auditor's empty-interval handling; the engine's own tests use
/// `vec![(); threads]` scratch).
#[test]
fn zst_and_empty_ranges_are_not_aliasing() {
    let engine = StepEngine::new().with_threads(2);
    let mut units = vec![(); 4];
    let unit_view = SharedSlice::new(&mut units);
    engine.run_tasks::<(), _>(2, 4, |_i, _| {
        // SAFETY: zero-sized elements — no bytes are ever written.
        let u = unsafe { unit_view.range_mut(0, 4) };
        assert_eq!(u.len(), 4);
    });
    let mut data = vec![0f32; 8];
    let view = SharedSlice::new(&mut data);
    engine.run_tasks::<(), _>(2, 4, |i, _| {
        // SAFETY: empty range — no bytes.
        let empty = unsafe { view.range_mut(3, 3) };
        assert!(empty.is_empty());
        // SAFETY: task i owns its own 2-element segment.
        let seg = unsafe { view.range_mut(i * 2, i * 2 + 2) };
        seg[0] += 1.0;
    });
    assert_eq!(data.iter().sum::<f32>(), 4.0);
}

// ---------------------------------------------------------------------
// Forced-steal schedules (sticky scheduler). `Affinity::force_owner`
// parks tasks on a chosen slot before the phase runs, so these tests
// pick the claim schedule instead of racing for one: steal storms (all
// tasks on one slot, every other worker's local queue empty), stolen
// dependency chains, and single-task plans. The executors' disjointness
// contract — and the auditor, under `--features audit` — must hold on
// stolen schedules exactly as on natural ones.
// ---------------------------------------------------------------------

/// All tasks parked on slot 0: every other worker starts with an empty
/// local block and runs purely on steals. Contents must land exactly as
/// under any other schedule, and the claim telemetry must account for
/// every task exactly once.
#[test]
fn steal_storm_keeps_disjoint_segments_intact() {
    const SEG: usize = 13;
    const TASKS: usize = 40;
    for &threads in &[2usize, 4, 7] {
        let engine = StepEngine::new()
            .with_threads(threads)
            .with_sched(SchedMode::Sticky);
        for seed in 60..66u64 {
            let mut aff = Affinity::new();
            for t in 0..TASKS {
                aff.force_owner(t, 0);
            }
            let mut data = vec![0u64; SEG * TASKS];
            let view = SharedSlice::new(&mut data);
            engine.run_tasks_in::<(), _>(threads, TASKS, &mut aff, |i, _| {
                jitter(seed, i);
                // SAFETY: task i owns segment i — pairwise disjoint.
                let seg = unsafe { view.range_mut(i * SEG, (i + 1) * SEG) };
                for (k, v) in seg.iter_mut().enumerate() {
                    *v = (i * SEG + k) as u64 + 1;
                }
            });
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, k as u64 + 1, "seed {seed}, {threads} threads, elem {k}");
            }
            let stats = aff.stats(SchedMode::Sticky);
            assert_eq!(stats.claims, TASKS as u64, "every task claimed exactly once");
            assert!(stats.steals <= stats.claims);
        }
    }
}

/// Deterministic steal storm: exactly `threads` tasks, all parked on
/// slot 0, each task gated on a barrier sized to the worker count. No
/// worker can finish its first task until every task has *started*, so
/// each worker ends up executing exactly one — which forces every
/// worker but slot 0 to steal. Claims and steals are exact, not racy.
#[test]
fn steal_storm_executes_on_every_worker() {
    use std::sync::Barrier;
    for &threads in &[2usize, 4] {
        let engine = StepEngine::new()
            .with_threads(threads)
            .with_sched(SchedMode::Sticky);
        let mut aff = Affinity::new();
        for t in 0..threads {
            aff.force_owner(t, 0);
        }
        let barrier = Barrier::new(threads);
        let mut data = vec![0u64; threads];
        let view = SharedSlice::new(&mut data);
        engine.run_tasks_in::<(), _>(threads, threads, &mut aff, |i, _| {
            barrier.wait();
            // SAFETY: task i owns element i — pairwise disjoint.
            let seg = unsafe { view.range_mut(i, i + 1) };
            seg[0] = i as u64 + 1;
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, k as u64 + 1, "{threads} threads, elem {k}");
        }
        let stats = aff.stats(SchedMode::Sticky);
        assert_eq!(stats.claims, threads as u64);
        assert_eq!(
            stats.steals,
            threads as u64 - 1,
            "every worker but the parked owner must steal its task"
        );
    }
}

/// The sticky dependency queue under a steal storm: every entry parked
/// on slot 0 while stride-`d` chains force cross-entry ordering. The
/// "smallest unfinished entry is always runnable" progress proof relies
/// on stealers taking the *front* of a victim's remaining block — this
/// drives exactly that path (and, under `--features audit`, proves the
/// auditor accepts ancestor-related range reuse on stolen schedules).
#[test]
fn dependency_chains_survive_forced_steals() {
    const SLOT: usize = 16;
    const LINKS: usize = 8;
    for &stride in &[1usize, 3] {
        for &threads in &[2usize, 4] {
            let n = LINKS * stride;
            let deps: Vec<Option<usize>> = (0..n)
                .map(|i| if i >= stride { Some(i - stride) } else { None })
                .collect();
            let engine = StepEngine::new()
                .with_threads(threads)
                .with_sched(SchedMode::Sticky);
            for seed in 70..76u64 {
                let mut aff = Affinity::new();
                for t in 0..n {
                    aff.force_owner(t, 0);
                }
                let mut data = vec![0u64; SLOT * stride];
                let view = SharedSlice::new(&mut data);
                let mut scratch = vec![0u8; threads];
                engine.run_tasks_dep_in(threads, &deps, &mut aff, &mut scratch, |i, _| {
                    jitter(seed, i);
                    let chain = i % stride;
                    // SAFETY: the chain's entries are dependency-ordered,
                    // so only one of them can hold this slot at a time.
                    let seg = unsafe { view.range_mut(chain * SLOT, (chain + 1) * SLOT) };
                    for v in seg.iter_mut() {
                        *v += (i + 1) as u64;
                    }
                });
                for c in 0..stride {
                    let want: u64 = (0..LINKS).map(|k| (c + k * stride + 1) as u64).sum();
                    for k in 0..SLOT {
                        assert_eq!(
                            data[c * SLOT + k],
                            want,
                            "stride {stride}, {threads} threads, seed {seed}, chain {c}"
                        );
                    }
                }
            }
        }
    }
}

/// Single-task plans: the degenerate claim queue (one block, everything
/// else empty) both unseeded and parked on the *last* slot, so the
/// claiming worker is a stealer whenever it isn't the owner.
#[test]
fn single_task_plans_run_under_sticky() {
    for &threads in &[1usize, 2, 5] {
        let engine = StepEngine::new()
            .with_threads(threads)
            .with_sched(SchedMode::Sticky);
        for owner in [None, Some(threads as u32 - 1)] {
            let mut aff = Affinity::new();
            if let Some(o) = owner {
                aff.force_owner(0, o);
            }
            let mut data = vec![0u64; 4];
            let view = SharedSlice::new(&mut data);
            engine.run_tasks_in::<(), _>(threads, 1, &mut aff, |_i, _| {
                // SAFETY: the only task owns the whole slice.
                let seg = unsafe { view.range_mut(0, 4) };
                for v in seg.iter_mut() {
                    *v += 7;
                }
            });
            assert!(data.iter().all(|&v| v == 7), "{threads} threads, owner {owner:?}");
            if threads > 1 {
                let stats = aff.stats(SchedMode::Sticky);
                assert_eq!(stats.claims, 1, "{threads} threads, owner {owner:?}");
            }
        }
    }
}

#[cfg(feature = "audit")]
mod negative {
    use super::*;
    use lowbit_opt::engine::audit;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    fn panic_message(err: Box<dyn std::any::Any + Send>) -> String {
        match err.downcast_ref::<String>() {
            Some(s) => s.clone(),
            None => err
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .unwrap_or_default(),
        }
    }

    /// The acceptance test: an intentionally overlapping `range_mut`
    /// pair aborts, and the report names **both** call sites (distinct
    /// lines of this file). Sequential execution (threads = 1) lets the
    /// original panic reach the caller intact.
    #[test]
    fn overlapping_views_abort_naming_both_sites() {
        let engine = StepEngine::new().with_threads(1);
        let mut data = vec![0u32; 16];
        let view = SharedSlice::new(&mut data);
        let err = catch_unwind(AssertUnwindSafe(|| {
            engine.run_tasks::<(), _>(1, 2, |i, _| {
                if i == 0 {
                    // Deliberate contract violation (elements 4..8 are
                    // claimed by both tasks) — the auditor must abort.
                    let a = unsafe { view.range_mut(0, 8) };
                    a[0] = 1;
                } else {
                    let b = unsafe { view.range_mut(4, 12) };
                    b[0] = 2;
                }
            });
        }))
        .expect_err("overlapping views must abort under the auditor");
        let msg = panic_message(err);
        assert!(msg.contains("overlapping live range_mut views"), "{msg}");
        assert!(msg.contains("task 0") && msg.contains("task 1"), "{msg}");
        let mut sites = std::collections::BTreeSet::new();
        for (pos, pat) in msg.match_indices("audit_stress.rs:") {
            let rest = &msg[pos + pat.len()..];
            let line: String = rest.chars().take_while(char::is_ascii_digit).collect();
            sites.insert(line);
        }
        assert!(
            sites.len() >= 2,
            "report must name both call sites on distinct lines: {msg}"
        );
    }

    /// Same violation on the real worker pool: the worker's abort is
    /// re-raised on the submitting thread (pool contract), so the run
    /// still fails loudly. Phase-scoped liveness makes this
    /// deterministic — the overlap is caught on *any* schedule.
    #[test]
    fn overlap_caught_on_the_worker_pool() {
        let engine = StepEngine::new().with_threads(2);
        let mut data = vec![0u32; 16];
        let view = SharedSlice::new(&mut data);
        let err = catch_unwind(AssertUnwindSafe(|| {
            engine.run_tasks::<(), _>(2, 2, |i, _| {
                // Deliberate contract violation: 4*i..4*i+8 overlap.
                let seg = unsafe { view.range_mut(4 * i, 4 * i + 8) };
                seg[0] = i as u32;
            });
        }))
        .expect_err("overlapping views must abort on the pool too");
        let msg = panic_message(err);
        assert!(
            msg.contains("overlapping live range_mut views")
                || msg.contains("engine worker panicked"),
            "{msg}"
        );
    }

    #[test]
    fn out_of_bounds_range_aborts() {
        let mut data = vec![0u32; 8];
        let view = SharedSlice::new(&mut data);
        let err = catch_unwind(AssertUnwindSafe(|| {
            // Deliberate out-of-bounds access — never materialized.
            let _ = unsafe { view.range_mut(4, 12) };
        }))
        .expect_err("out-of-bounds range must abort under the auditor");
        let msg = panic_message(err);
        assert!(msg.contains("out-of-bounds"), "{msg}");
    }

    /// A task scope that survives into a later phase (a worker running
    /// past the pool drain) is stale: its next access aborts.
    #[test]
    fn stale_task_scope_aborts() {
        let reg = Arc::new(audit::Registry::new());
        let phase1 = audit::phase_scope(&reg, None);
        let _task = audit::task_scope(&reg, 0);
        drop(phase1);
        let _phase2 = audit::phase_scope(&reg, None);
        let err = catch_unwind(AssertUnwindSafe(|| {
            audit::check_range(0x1000, 4, 16, 0, 8);
        }))
        .expect_err("stale task scope must abort");
        let msg = panic_message(err);
        assert!(msg.contains("outlives its phase barrier"), "{msg}");
    }
}
