//! Property tests (via `util/propcheck`) for the offload tier's staging
//! layout, `offload::tier::build_tier_plan`. The tier's transfer tasks
//! cite these invariants at their `unsafe` slot accesses, and the link
//! accounting (`offload::link`) trusts the recorded byte counts, so both
//! get hammered here across arbitrary mixes of state storage forms:
//!
//! * staged segments of one task are pairwise disjoint within the slot's
//!   byte arena and within its f32 arena, and every extent fits the
//!   task's recorded footprint, which in turn fits the slot budget;
//! * the recorded link traffic is exactly the sum over staged segments
//!   (down: all segments; up: writeback segments only);
//! * phase-C stagings exist precisely for tasks touching a
//!   globally-normalized state, stage only those states, and carry no
//!   scale values (global scales stay device-resident);
//! * the layout is a pure function of (plan, state forms);
//! * the dense-fp32 layout (`build_dense_tier_plan`) stages both moments
//!   as plain f32 — per-step traffic exactly `2 × 4 bytes × numel` each
//!   way, the analytic model's assumption.

use lowbit_opt::engine::plan::{build_plan, StateLayout, TensorMeta};
use lowbit_opt::offload::tier::{build_dense_tier_plan, build_tier_plan, StagedState, TaskStaging};
use lowbit_opt::optim::factor::FactoredSecond;
use lowbit_opt::optim::state::{MomentState, SecondState};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::tensor::Tensor;
use lowbit_opt::util::propcheck::{check, Gen};

fn gen_shape(g: &mut Gen) -> Vec<usize> {
    match g.rng.below(8) {
        0..=3 => vec![1 + g.rng.below(5000)],
        4..=6 => vec![1 + g.rng.below(40), 1 + g.rng.below(90)],
        _ => vec![1 + g.rng.below(10), 1 + g.rng.below(8), 1 + g.rng.below(9)],
    }
}

/// Deterministic strictly-positive payload: positivity sidesteps the
/// quantizers' zero-scale special cases (not under test here) and keeps
/// unsigned second-moment forms in range.
fn test_tensor(shape: &[usize], salt: u64) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n as u64)
        .map(|i| 0.25 + ((i * 7 + salt) % 13) as f32)
        .collect();
    Tensor::from_vec(shape, data)
}

fn gen_m(g: &mut Gen, shape: &[usize]) -> MomentState {
    let t = test_tensor(shape, 1);
    match g.rng.below(4) {
        0 => MomentState::F32(t),
        1 => {
            let q = Quantizer::first_moment_4bit().quantize(&t, &mut g.rng);
            MomentState::Quant(q)
        }
        2 => {
            let q = Quantizer::moment_8bit(true).quantize(&t, &mut g.rng);
            MomentState::Quant(q)
        }
        _ => {
            // Globally-normalized m: rank-1 on matrices, per-tensor else.
            let norm = if shape.len() == 2 && g.rng.below(2) == 0 {
                NormKind::Rank1
            } else {
                NormKind::PerTensor
            };
            let q = Quantizer::new(norm, MapKind::DynExp, 4, true).quantize(&t, &mut g.rng);
            MomentState::Quant(q)
        }
    }
}

fn gen_v(g: &mut Gen, shape: &[usize]) -> SecondState {
    let t = test_tensor(shape, 2);
    match g.rng.below(5) {
        0 => SecondState::F32(t),
        1 if shape.len() == 2 => {
            let q = Quantizer::second_moment_4bit().quantize(&t, &mut g.rng);
            SecondState::Quant(q)
        }
        2 => {
            let q = Quantizer::moment_8bit(false).quantize(&t, &mut g.rng);
            SecondState::Quant(q)
        }
        3 if shape.len() >= 2 => SecondState::Factored(FactoredSecond::zeros(shape)),
        _ => {
            let q = Quantizer::new(NormKind::Block(64), MapKind::Linear, 4, false)
                .quantize(&t, &mut g.rng);
            SecondState::Quant(q)
        }
    }
}

/// Planner layout + stat-slot length for a quantized state — mirrors the
/// derivation the compressed executor feeds the planner (`engine/adamw4`),
/// so the generated metas are exactly what a real step would use.
fn layout_for(q: &Quantizer, shape: &[usize]) -> (StateLayout, usize) {
    match q.norm {
        NormKind::Block(b) => (StateLayout::Block(b), 0),
        NormKind::Rank1 if shape.len() >= 2 => (StateLayout::Global, shape.iter().sum()),
        _ => (StateLayout::Global, 1),
    }
}

struct Inputs {
    metas: Vec<TensorMeta>,
    m_states: Vec<MomentState>,
    v_states: Vec<SecondState>,
    shard: usize,
}

fn gen_inputs(g: &mut Gen) -> Inputs {
    let n = 1 + g.rng.below(6);
    let mut metas = Vec::with_capacity(n);
    let mut m_states = Vec::with_capacity(n);
    let mut v_states = Vec::with_capacity(n);
    for _ in 0..n {
        let shape = gen_shape(g);
        let numel: usize = shape.iter().product();
        let ms = gen_m(g, &shape);
        let vs = gen_v(g, &shape);
        let (m, m_stat_len) = match &ms {
            MomentState::F32(_) => (StateLayout::F32, 0),
            MomentState::Quant(q) => layout_for(&q.quantizer, &shape),
        };
        let (v, v_stat_len) = match &vs {
            SecondState::F32(_) => (StateLayout::F32, 0),
            SecondState::Quant(q) => layout_for(&q.quantizer, &shape),
            SecondState::Factored(f) => (StateLayout::Factored, f.rows() + f.cols()),
        };
        metas.push(TensorMeta {
            numel,
            shape,
            m,
            v,
            m_stat_len,
            v_stat_len,
        });
        m_states.push(ms);
        v_states.push(vs);
    }
    let shard = *g.choose(&[2usize, 64, 512, 4096]);
    Inputs {
        metas,
        m_states,
        v_states,
        shard,
    }
}

/// All staged segments of one task staging, in layout order.
fn segs(ts: &TaskStaging) -> Vec<StagedState> {
    ts.pieces
        .iter()
        .flat_map(|p| [p.m, p.v])
        .flatten()
        .collect()
}

/// Non-empty intervals must be pairwise disjoint and lie in `[0, len)`.
fn check_disjoint(
    mut iv: Vec<(usize, usize)>,
    len: usize,
    what: &str,
    task: usize,
) -> Result<(), String> {
    iv.retain(|&(a, b)| a != b);
    iv.sort_unstable();
    let mut prev = 0usize;
    for &(a, b) in &iv {
        if b > len {
            return Err(format!(
                "task {task}: {what} segment [{a}, {b}) exceeds the arena length {len}"
            ));
        }
        if a < prev {
            return Err(format!(
                "task {task}: {what} segment [{a}, {b}) overlaps the previous one (ends {prev})"
            ));
        }
        prev = b;
    }
    Ok(())
}

#[test]
fn prop_staged_segments_disjoint_and_within_budget() {
    check("tier segment disjointness + slot budget", 200, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let tp = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        for ts in tp.a.iter().chain(tp.c.iter()) {
            if ts.pieces.len() != plan.tasks[ts.task].pieces.len() {
                return Err(format!(
                    "task {}: {} piece stagings for {} plan pieces",
                    ts.task,
                    ts.pieces.len(),
                    plan.tasks[ts.task].pieces.len()
                ));
            }
            let ss = segs(ts);
            let bytes: Vec<_> = ss
                .iter()
                .map(|s| (s.bytes_off, s.bytes_off + s.bytes_len))
                .collect();
            let vals: Vec<_> = ss
                .iter()
                .map(|s| (s.vals_off, s.vals_off + s.vals_len))
                .collect();
            check_disjoint(bytes, ts.bytes_len, "byte-arena", ts.task)?;
            check_disjoint(vals, ts.vals_len, "f32-arena", ts.task)?;
            if ts.bytes_len > tp.slot_bytes || ts.vals_len > tp.slot_vals {
                return Err(format!(
                    "task {}: footprint ({}, {}) exceeds slot budget ({}, {})",
                    ts.task, ts.bytes_len, ts.vals_len, tp.slot_bytes, tp.slot_vals
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_recorded_traffic_matches_staged_segments() {
    check("tier traffic accounting", 200, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let tp = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        let (mut down_total, mut up_total) = (0u64, 0u64);
        for ts in tp.a.iter().chain(tp.c.iter()) {
            let (mut down, mut up) = (0u64, 0u64);
            for s in segs(ts) {
                let bytes = s.bytes_len as u64 + 4 * s.vals_len as u64;
                down += bytes;
                if s.writeback {
                    up += bytes;
                }
            }
            if (down, up) != (ts.down_bytes, ts.up_bytes) {
                return Err(format!(
                    "task {}: recorded traffic ({}, {}) != segment sum ({down}, {up})",
                    ts.task, ts.down_bytes, ts.up_bytes
                ));
            }
            down_total += down;
            up_total += up;
        }
        if tp.step_traffic() != (down_total, up_total) {
            return Err(format!(
                "step_traffic {:?} != per-task sums ({down_total}, {up_total})",
                tp.step_traffic()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_phase_c_stages_exactly_the_global_states() {
    check("tier phase-C structure", 200, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let tp = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        // Phase A: one staging per plan task, in order; m always staged,
        // v staged unless factored (resident).
        if tp.a.len() != plan.tasks.len() {
            return Err(format!(
                "{} phase-A stagings for {} plan tasks",
                tp.a.len(),
                plan.tasks.len()
            ));
        }
        for (i, ts) in tp.a.iter().enumerate() {
            if ts.task != i {
                return Err(format!("phase-A staging {i} names task {}", ts.task));
            }
            for (ps, p) in ts.pieces.iter().zip(&plan.tasks[i].pieces) {
                let meta = &inp.metas[p.tensor];
                if ps.m.is_none() {
                    return Err(format!("task {i}: phase A left m of tensor {} out", p.tensor));
                }
                if ps.v.is_some() == (meta.v == StateLayout::Factored) {
                    return Err(format!(
                        "task {i}: phase A v staging mismatch for tensor {} ({:?})",
                        p.tensor, meta.v
                    ));
                }
            }
        }
        // Phase C: stagings exactly for tasks with a Global state; only
        // the Global states are staged, codes only, always written back.
        let want: Vec<usize> = plan
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                t.pieces.iter().any(|p| {
                    inp.metas[p.tensor].m == StateLayout::Global
                        || inp.metas[p.tensor].v == StateLayout::Global
                })
            })
            .map(|(i, _)| i)
            .collect();
        let got: Vec<usize> = tp.c.iter().map(|ts| ts.task).collect();
        if got != want {
            return Err(format!("phase-C tasks {got:?} != tasks with globals {want:?}"));
        }
        for ts in &tp.c {
            for (ps, p) in ts.pieces.iter().zip(&plan.tasks[ts.task].pieces) {
                let meta = &inp.metas[p.tensor];
                if ps.m.is_some() != (meta.m == StateLayout::Global)
                    || ps.v.is_some() != (meta.v == StateLayout::Global)
                {
                    return Err(format!(
                        "task {}: phase C staged a non-global state of tensor {}",
                        ts.task, p.tensor
                    ));
                }
                for s in [ps.m, ps.v].into_iter().flatten() {
                    if s.vals_len != 0 || !s.writeback {
                        return Err(format!(
                            "task {}: phase C segment must be codes-only writeback \
                             (vals_len {}, writeback {})",
                            ts.task, s.vals_len, s.writeback
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tier_plan_is_pure_in_its_inputs() {
    check("tier plan purity", 100, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let a = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        let b = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        if (a.slot_bytes, a.slot_vals) != (b.slot_bytes, b.slot_vals)
            || a.step_traffic() != b.step_traffic()
            || a.a.len() != b.a.len()
            || a.c.len() != b.c.len()
        {
            return Err("rebuild changed the staging layout".into());
        }
        for (x, y) in a.a.iter().chain(a.c.iter()).zip(b.a.iter().chain(b.c.iter())) {
            if (x.task, x.bytes_len, x.vals_len) != (y.task, y.bytes_len, y.vals_len) {
                return Err(format!("rebuild changed task {} staging", x.task));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dense_tier_plan_is_pure_f32_staging() {
    check("dense tier staging", 100, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let tp = build_dense_tier_plan(&plan);
        if !tp.c.is_empty() || tp.slot_bytes != 0 {
            return Err(format!(
                "dense staging grew codes or a phase C ({} bytes, {} stagings)",
                tp.slot_bytes,
                tp.c.len()
            ));
        }
        let total: u64 = plan.total_elems as u64;
        if tp.step_traffic() != (8 * total, 8 * total) {
            return Err(format!(
                "dense step traffic {:?} != 8 bytes × {total} each way",
                tp.step_traffic()
            ));
        }
        for (i, ts) in tp.a.iter().enumerate() {
            if ts.bytes_len != 0 {
                return Err(format!("dense task {i} staged {} code bytes", ts.bytes_len));
            }
            for (ps, p) in ts.pieces.iter().zip(&plan.tasks[i].pieces) {
                for s in [ps.m, ps.v] {
                    let Some(s) = s else {
                        return Err(format!("dense task {i} skipped a moment"));
                    };
                    if s.vals_len != p.len() || !s.writeback {
                        return Err(format!(
                            "dense task {i}: segment stages {} of {} elements",
                            s.vals_len,
                            p.len()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The staging layout is consistent with the *plan* invariants the
/// executors rely on: a task's staged element count never exceeds its
/// plan pieces' element count (staging introduces no duplication).
#[test]
fn prop_staged_vals_bounded_by_piece_elems() {
    check("tier staging vs piece extents", 200, |g| {
        let inp = gen_inputs(g);
        let plan = build_plan(&inp.metas, inp.shard);
        let tp = build_tier_plan(&plan, &inp.metas, &inp.m_states, &inp.v_states);
        for ts in &tp.a {
            for (ps, p) in ts.pieces.iter().zip(&plan.tasks[ts.task].pieces) {
                for s in [ps.m, ps.v].into_iter().flatten() {
                    // A staged f32 run is either a full per-element copy
                    // (fp32 state) or a per-block scale run — never more
                    // values than the piece has elements.
                    if s.vals_len > p.len() {
                        return Err(format!(
                            "task {}: segment stages {} f32 values for a {}-element piece",
                            ts.task,
                            s.vals_len,
                            p.len()
                        ));
                    }
                    // Codes never exceed one byte per element.
                    if s.bytes_len > p.len() {
                        return Err(format!(
                            "task {}: segment stages {} code bytes for a {}-element piece",
                            ts.task,
                            s.bytes_len,
                            p.len()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
